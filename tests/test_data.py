"""Data pipeline: padding semantics, determinism, resumability."""
import numpy as np

from repro.data.batching import DataIterator, plan_epoch
from repro.data.synthetic import IWSLT_LIKE, LIBRISPEECH_LIKE


def test_max_pad_semantics():
    sls = np.array([3, 9, 5, 7, 2, 8, 1, 4])
    plan = plan_epoch(sls, 4, granularity=4, seed=0)
    for p, members in zip(plan.padded_sls, plan.member_sls):
        assert p >= members.max()
        assert p % 4 == 0


def test_sort_first_epoch_orders_sls():
    sls = np.array([30, 1, 20, 5, 10, 2, 40, 3])
    plan = plan_epoch(sls, 2, granularity=1, sort_first=True)
    assert list(plan.padded_sls) == sorted(plan.padded_sls)


def test_distributions_in_range():
    rng = np.random.RandomState(0)
    for dist in (IWSLT_LIKE, LIBRISPEECH_LIKE):
        s = dist.sample(rng, 5000)
        assert s.min() >= dist.min_len and s.max() <= dist.max_len
        assert len(np.unique(s)) > 20


def test_iterator_deterministic_and_resumable():
    def make():
        return DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=16,
                            vocab_size=1000, granularity=4, seed=7)

    it1 = iter(make())
    ref = [next(it1) for _ in range(10)]

    # fresh iterator replays identically
    it2 = iter(make())
    for tok_ref, lab_ref, sl_ref in ref:
        tok, lab, sl = next(it2)
        assert sl == sl_ref
        np.testing.assert_array_equal(tok, tok_ref)
        np.testing.assert_array_equal(lab, lab_ref)

    # resume from the recorded state mid-epoch
    d3 = make()
    it3 = iter(d3)
    for _ in range(6):
        next(it3)
    state = d3.state()
    d4 = make()
    d4.restore(state)
    it4 = iter(d4)
    for i in range(6, 10):
        tok, lab, sl = next(it4)
        assert sl == ref[i][2]
        np.testing.assert_array_equal(tok, ref[i][0])


def test_shards_consistent_sl_schedule():
    kw = dict(samples_per_epoch=128, batch_size=16, vocab_size=500,
              granularity=2, seed=3)
    a = iter(DataIterator(IWSLT_LIKE, shard_id=0, num_shards=4, **kw))
    b = iter(DataIterator(IWSLT_LIKE, shard_id=3, num_shards=4, **kw))
    for _ in range(6):
        ta, la, sa = next(a)
        tb, lb, sb = next(b)
        assert sa == sb                     # lockstep padded shapes
        assert ta.shape == tb.shape == (4, sa)
        assert not np.array_equal(ta, tb)   # different shards
