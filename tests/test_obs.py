"""repro.obs: tracer, metrics, events, projection monitor, trainer wiring."""
import json
import os
import threading

import numpy as np
import pytest

from repro import obs
from repro.core.profile import EpochLog
from repro.core.seqpoint import select_seqpoints
from repro.obs.events import EventSink
from repro.obs.metrics import MetricsRegistry, bucket_bound
from repro.obs.trace import NULL_SPAN, Tracer


@pytest.fixture
def tracer():
    """Fresh enabled tracer installed as the global one."""
    t = Tracer(enabled=True)
    prev = obs.set_tracer(t)
    yield t
    obs.set_tracer(prev)


@pytest.fixture
def sink(tmp_path):
    s = EventSink(str(tmp_path / "events.jsonl"), flush_every=1)
    prev = obs.set_sink(s)
    yield s
    obs.set_sink(prev)
    s.close()


# -------------------------------------------------------------------- trace


def test_span_nesting_records_depth_and_containment(tracer):
    with obs.span("outer", sl=128):
        assert tracer.current_span() == "outer"
        with obs.span("inner"):
            assert tracer.current_span() == "inner"
    assert tracer.current_span() is None
    by_name = {e["name"]: e for e in tracer.events}
    assert set(by_name) == {"outer", "inner"}
    outer, inner = by_name["outer"], by_name["inner"]
    assert inner["args"]["depth"] == 1
    assert outer["args"]["sl"] == 128
    # child fully contained in parent
    assert inner["ts"] >= outer["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6


def test_disabled_tracer_is_zero_cost_noop():
    t = Tracer(enabled=False)
    prev = obs.set_tracer(t)
    try:
        s1 = obs.span("a", x=1)
        s2 = obs.span("b")
        # one shared null span object: no allocation, no clock reads
        assert s1 is s2 is NULL_SPAN
        with s1:
            pass
        assert t.events == []
        assert s1.set(y=2) is NULL_SPAN
    finally:
        obs.set_tracer(prev)


def test_chrome_trace_export_roundtrips(tracer, tmp_path):
    with obs.span("train/step", step=3):
        with obs.span("train/step_fn"):
            pass
    path = tracer.export_chrome_trace(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)                      # must be valid JSON
    names = [e["name"] for e in doc["traceEvents"]]
    assert sorted(names) == ["train/step", "train/step_fn"]
    for e in doc["traceEvents"]:
        assert e["ph"] == "X" and e["dur"] >= 0 and "pid" in e and "tid" in e


def test_traced_decorator_and_threads(tracer):
    @obs.traced("worker/fn")
    def fn():
        return 7

    th = threading.Thread(target=fn)
    th.start()
    th.join()
    assert fn() == 7
    events = [e for e in tracer.events if e["name"] == "worker/fn"]
    assert len(events) == 2
    assert len({e["tid"] for e in events}) == 2   # distinct thread ids


# ------------------------------------------------------------------ metrics


def test_histogram_log2_bucket_boundaries():
    # exact powers of two land on their own bound; everything else rounds up
    assert bucket_bound(1.0) == 1.0
    assert bucket_bound(2.0) == 2.0
    assert bucket_bound(1.0001) == 2.0
    assert bucket_bound(0.5) == 0.5
    assert bucket_bound(0.51) == 1.0
    assert bucket_bound(0.0) == 0.0
    assert bucket_bound(-3.0) == 0.0

    reg = MetricsRegistry()
    h = reg.histogram("t", sl=64)
    for v in (0.5, 1.0, 1.5, 2.0, 3.0):
        h.observe(v)
    assert h.buckets == {0.5: 1, 1.0: 1, 2.0: 2, 4.0: 1}
    assert h.count == 5 and h.min == 0.5 and h.max == 3.0
    assert h.cumulative() == [(0.5, 1), (1.0, 2), (2.0, 4), (4.0, 5)]


def test_registry_snapshot_prometheus_and_type_conflict():
    reg = MetricsRegistry()
    reg.counter("steps", job="train").inc(3)
    reg.gauge("depth").set(2)
    reg.histogram("lat_s", sl=32).observe(0.25)
    snap = reg.snapshot()
    assert snap["steps"][0]["value"] == 3
    assert snap["steps"][0]["labels"] == {"job": "train"}
    assert snap["lat_s"][0]["buckets"] == {"0.25": 1}
    json.loads(reg.to_json())                   # JSON-serializable
    prom = reg.to_prometheus()
    assert 'steps{job="train"} 3' in prom
    assert 'lat_s_bucket{sl="32",le="+Inf"} 1' in prom
    assert 'lat_s_count{sl="32"} 1' in prom
    with pytest.raises(TypeError):
        reg.gauge("steps", job="train")


# ------------------------------------------------------------------- events


def test_event_sink_flush_and_sequencing(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    s = EventSink(path, flush_every=2)
    s.emit("a", x=1)
    assert not os.path.exists(path)             # buffered
    s.emit("b")
    recs = [json.loads(l) for l in open(path)]  # flushed at 2
    assert [r["kind"] for r in recs] == ["a", "b"]
    assert [r["seq"] for r in recs] == [0, 1]
    assert all("ts" in r for r in recs)
    s.emit("c")
    s.close()                                   # close flushes the tail
    recs = [json.loads(l) for l in open(path)]
    assert [r["kind"] for r in recs] == ["a", "b", "c"]


def test_event_sink_rotation(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    s = EventSink(path, flush_every=1, max_bytes=200)
    for i in range(20):
        s.emit("fill", i=i, pad="x" * 40)
    s.close()
    assert os.path.exists(path + ".1")          # rotated generation
    for p in (path, path + ".1"):
        for line in open(p):
            json.loads(line)                    # every line parses


def test_module_event_noop_without_sink():
    prev = obs.set_sink(None)
    try:
        assert obs.event("anything", x=1) is None
    finally:
        obs.set_sink(prev)


# --------------------------------------------------------------- projection


def _synthetic_log(scale=1.0):
    log = EpochLog()
    for sl, rt, n in ((16, 0.1, 30), (32, 0.2, 20), (64, 0.4, 10)):
        for _ in range(n):
            log.append(sl, rt * scale)
    return log


def test_projection_monitor_exact_on_selection_log():
    log = _synthetic_log()
    sp = select_seqpoints(log)                   # all-unique: exact
    mon = obs.ProjectionMonitor(sp)
    mon.observe_log(log)
    rep = mon.report()
    assert rep.iterations == 60
    assert rep.rel_error < 1e-9
    assert rep.eq1_predicted == pytest.approx(sp.predicted)
    assert len(rep.per_sl) == 3
    for r in rep.per_sl:
        assert abs(r.residual) < 1e-12


def test_projection_monitor_detects_drift():
    sp = select_seqpoints(_synthetic_log())
    mon = obs.ProjectionMonitor(sp)
    mon.observe_log(_synthetic_log(scale=1.25))  # hardware got 25% slower
    rep = mon.report()
    assert rep.rel_error == pytest.approx(0.2, abs=1e-6)  # 1/1.25 short
    worst = rep.worst_sl()
    assert worst is not None and worst.residual > 0
    # per-SL: measured mean exceeds prediction by exactly 25%
    for r in rep.per_sl:
        assert r.measured_mean == pytest.approx(r.predicted * 1.25)


def test_collective_projection_report_aggregates():
    from repro.obs.projection import collective_projection_report

    records = [
        {"arch": "a", "shape": "s", "mesh": "16x16", "status": "ok",
         "projection": {"rel_error": 0.1, "analytic_wire_bytes": 1.0,
                        "measured_wire_bytes": 1.1}},
        {"arch": "b", "shape": "s", "mesh": "16x16", "status": "error"},
        {"arch": "c", "shape": "s", "mesh": "16x16", "status": "ok",
         "projection": {"rel_error": 0.4, "analytic_wire_bytes": 2.0,
                        "measured_wire_bytes": 1.2}},
    ]
    rep = collective_projection_report(records, error_bound=0.5)
    assert rep["num_cells"] == 2
    assert rep["max_rel_error"] == pytest.approx(0.4)
    assert rep["within_bound"] is True
    assert not collective_projection_report(
        records, error_bound=0.2)["within_bound"]


def test_analytic_wire_bytes_decode_uses_single_token():
    from repro.configs import get_model_config, get_shape
    from repro.dist.sharding import tp_activation_wire_bytes
    from repro.obs.projection import analytic_wire_bytes

    cfg = get_model_config("starcoder2-3b")
    decode = get_shape("decode_32k")
    a = analytic_wire_bytes(cfg, decode, parallelism="tp", dp_degree=16,
                            tp_degree=16)
    assert a["dp_grad"] == 0.0                   # no grads when serving
    # one token through the stack, regardless of the 32k cache
    expected = tp_activation_wire_bytes(cfg, decode.global_batch, 1, 16,
                                        training=False)
    assert a["tp_activation"] == pytest.approx(expected)
    assert a["tp_activation"] > 0
    assert a["total"] == pytest.approx(a["tp_activation"])


def test_analytic_wire_bytes_grad_dtype_and_zero_micro_reduces():
    from repro.configs import get_model_config, get_shape
    from repro.obs.projection import analytic_wire_bytes

    cfg = get_model_config("starcoder2-3b")
    train = get_shape("train_4k")
    base = analytic_wire_bytes(cfg, train, parallelism="tp", dp_degree=4,
                               tp_degree=4)
    bf16 = analytic_wire_bytes(cfg, train, parallelism="tp", dp_degree=4,
                               tp_degree=4, grad_dtype_bytes=2.0)
    assert bf16["dp_grad"] == pytest.approx(base["dp_grad"] / 2)
    assert bf16["tp_activation"] == pytest.approx(base["tp_activation"])
    micro = analytic_wire_bytes(cfg, train, parallelism="tp", dp_degree=4,
                                tp_degree=4, micro_reduces=4)
    assert micro["dp_grad"] == pytest.approx(4 * base["dp_grad"])


def test_cell_projection_micro_counted_normalizes_rolled_scan():
    # compile-mode HLO rolls the microbatch scan: measured stats contain
    # one microbatch body, so the analytic dp term must not be multiplied
    # by the full microbatch count
    from repro.configs import MeshConfig, RunConfig, get_model_config, \
        get_shape
    from repro.obs.projection import cell_collective_projection
    from repro.perfmodel.hlo import CollectiveStats

    cfg = get_model_config("starcoder2-3b")
    train = get_shape("train_4k")
    run = RunConfig(model=cfg, shape=train,
                    mesh=MeshConfig(shape=(4, 4), axes=("data", "model")),
                    fsdp=True, microbatches=4)
    assert run.zero_stage >= 3 and run.compute_dtype == "bfloat16"
    measured = CollectiveStats()
    measured.count["all-reduce"] = 1
    measured.buffer_bytes["all-reduce"] = 10**9
    measured.count["all-gather"] = 4
    measured.buffer_bytes["all-gather"] = 10**9
    rolled = cell_collective_projection(cfg, train, run, measured,
                                        micro_counted=1)
    full = cell_collective_projection(cfg, train, run, measured)
    assert rolled["micro_reduces"] == 4 and rolled["micro_counted"] == 1
    assert full["micro_counted"] == 4
    assert full["analytic_dp_bytes"] == \
        pytest.approx(4 * rolled["analytic_dp_bytes"])
    assert rolled["grad_dtype_bytes"] == 2.0
    # the claimed residual compares against all-reduce wire only; the
    # ZeRO all-gather stays in measured_wire_bytes but not in claimed
    assert rolled["measured_claimed_wire_bytes"] < \
        rolled["measured_reduce_wire_bytes"] <= rolled["measured_wire_bytes"]
    assert "rel_error_claimed" in rolled
    # spec-derived DP ring size overrides the param-count assumption
    shrunk = cell_collective_projection(cfg, train, run, measured,
                                        micro_counted=1,
                                        dp_reduce_elems=1000.0)
    assert shrunk["dp_reduce_elems"] == 1000.0
    assert shrunk["analytic_dp_bytes"] < rolled["analytic_dp_bytes"]


# ------------------------------------------------------- end-to-end trainer


def test_trainer_emits_spans_metrics_and_straggler_events(tracer, sink):
    from repro.configs import MeshConfig, OptimizerConfig, RunConfig, \
        ShapeConfig, StepKind, smoke_config
    from repro.data.batching import DataIterator
    from repro.data.synthetic import IWSLT_LIKE
    from repro.models import Runtime, build_model
    from repro.train.trainer import Trainer

    obs.metrics.reset()
    cfg = smoke_config("starcoder2-3b").with_overrides(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=8,
                        step=StepKind.TRAIN)
    run = RunConfig(model=cfg, shape=shape,
                    mesh=MeshConfig(shape=(1,), axes=("data",)),
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
                    param_dtype="float32", compute_dtype="float32")
    data = DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                        vocab_size=cfg.vocab_size, granularity=8, seed=1)
    model = build_model(cfg, Runtime.from_run(run))
    tr = Trainer(model, run, data, straggler_factor=1e-9, total_steps=8)
    rep = tr.train(5)
    assert rep.steps == 5

    names = [e["name"] for e in tracer.events]
    for expected in ("train/step", "train/data_fetch", "train/step_fn",
                     "train/block_until_ready"):
        assert names.count(expected) == 5, expected
    # step spans carry the padded SL attribute
    step_evs = [e for e in tracer.events if e["name"] == "train/step"]
    assert all("sl" in e["args"] for e in step_evs)

    sink.flush()
    evs = [json.loads(l) for l in open(sink.path)]
    kinds = [e["kind"] for e in evs]
    assert kinds[0] == "train_start" and kinds[-1] == "train_end"
    stragglers = [e for e in evs if e["kind"] == "straggler"]
    assert len(stragglers) == rep.stragglers >= 1
    assert all({"step", "sl", "dt", "baseline"} <= set(e) for e in
               stragglers)

    snap = obs.metrics.snapshot()
    assert snap["train_steps_total"][0]["value"] == 5
    hist = snap["train_step_time_s"]
    assert sum(h["count"] for h in hist) == 5
    assert all("sl" in h["labels"] for h in hist)     # SL-keyed
    obs.metrics.reset()


def test_trainer_disabled_obs_keeps_log_identical():
    """With obs off (default), training still logs the epoch normally and
    no trace events or sink writes happen."""
    from repro.configs import MeshConfig, OptimizerConfig, RunConfig, \
        ShapeConfig, StepKind, smoke_config
    from repro.data.batching import DataIterator
    from repro.data.synthetic import IWSLT_LIKE
    from repro.models import Runtime, build_model
    from repro.train.trainer import Trainer

    assert obs.get_sink() is None and not obs.tracing_enabled()
    cfg = smoke_config("starcoder2-3b").with_overrides(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=8,
                        step=StepKind.TRAIN)
    run = RunConfig(model=cfg, shape=shape,
                    mesh=MeshConfig(shape=(1,), axes=("data",)),
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
                    param_dtype="float32", compute_dtype="float32")
    data = DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                        vocab_size=cfg.vocab_size, granularity=8, seed=1)
    model = build_model(cfg, Runtime.from_run(run))
    tr = Trainer(model, run, data, total_steps=4)
    rep = tr.train(3)
    assert rep.steps == 3 and tr.epoch_log.num_iterations == 3
    assert obs.get_tracer().events == []


# ------------------------------------------------------- live scrape endpoint


def test_serve_http_scrapes_live_metrics():
    """The background endpoint renders a fresh to_prometheus() per scrape
    (live values, not snapshot-at-exit) and shuts down cleanly."""
    import urllib.request

    reg = MetricsRegistry()
    reg.counter("scrape_demo_total", sl=64).inc(2)
    with obs.serve_http(registry=reg) as srv:
        assert srv.port > 0
        body = urllib.request.urlopen(srv.url, timeout=5).read().decode()
        assert '# TYPE scrape_demo_total counter' in body
        assert 'scrape_demo_total{sl="64"} 2' in body
        # live: a later increment shows up on the next scrape
        reg.counter("scrape_demo_total", sl=64).inc()
        body = urllib.request.urlopen(srv.url, timeout=5).read().decode()
        assert 'scrape_demo_total{sl="64"} 3' in body
        # index points at /metrics; unknown paths 404
        idx = urllib.request.urlopen(
            f"http://{srv.addr}:{srv.port}/", timeout=5).read().decode()
        assert "/metrics" in idx
        with pytest.raises(Exception):
            urllib.request.urlopen(
                f"http://{srv.addr}:{srv.port}/nope", timeout=5)
