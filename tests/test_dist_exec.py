"""Multi-device execution correctness (8 fake host devices, subprocess so
the device count doesn't leak into other tests)."""
import os
import subprocess
import sys

import pytest

HELPER = os.path.join(os.path.dirname(__file__), "helpers", "dist_check.py")


@pytest.mark.parametrize("arch", [
    "qwen2-moe-a2.7b",        # shard_map EP/TP MoE path
    "mistral-nemo-12b",       # GQA dense
    "jamba-v0.1-52b",         # hybrid mamba + MoE
    "rwkv6-3b",               # attention-free, padded heads
])
def test_sharded_matches_unsharded(arch):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, HELPER, arch],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert res.returncode == 0, \
        f"{arch}: {res.stdout[-1000:]}\n{res.stderr[-2000:]}"
    assert "MATCH" in res.stdout
