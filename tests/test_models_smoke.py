"""Per-arch smoke: reduced config, one fwd/bwd step + one decode step on CPU,
asserting shapes and finiteness (assignment requirement)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_model_config, list_archs, smoke_config, \
    shapes_for
from repro.models import Runtime, build_model


@pytest.mark.parametrize("arch", list_archs())
def test_train_and_decode_smoke(arch):
    cfg = smoke_config(arch)
    model = build_model(cfg, Runtime())
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B, S = 2, 64
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.frontend == "image_patches":
        batch["patches"] = jax.random.normal(rng, (B, 16, cfg.d_model))
    if cfg.frontend == "audio_frames":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encoder.max_source_len, cfg.d_model))

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p, b: model.loss(p, b)[0]))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
        grads, jnp.zeros(()))
    assert bool(jnp.isfinite(gnorm)), arch

    caches = model.init_cache(B, 32)
    logits, new_caches = jax.jit(model.decode_step)(
        params, caches, jnp.zeros((B, 1), jnp.int32),
        jnp.array(3, jnp.int32))
    assert logits.shape[0] == B
    assert bool(jnp.all(jnp.isfinite(logits))), arch


@pytest.mark.parametrize("arch", list_archs())
def test_assigned_dims_preserved(arch):
    """The full config carries the exact published dims (spot invariants)."""
    cfg = get_model_config(arch)
    assert cfg.num_layers >= 24
    assert cfg.vocab_size > 4000
    shapes = shapes_for(cfg)
    names = {s.name for s in shapes}
    assert {"train_4k", "prefill_32k", "decode_32k"} <= names
    if cfg.subquadratic:
        assert "long_500k" in names
    else:
        assert "long_500k" not in names


def test_specific_dims():
    ds = get_model_config("deepseek-v3-671b")
    assert (ds.num_layers, ds.d_model, ds.num_heads) == (61, 7168, 128)
    assert ds.moe.num_experts == 256 and ds.moe.experts_per_token == 8
    assert ds.mla.kv_lora_rank == 512
    q72 = get_model_config("qwen2-72b")
    assert (q72.num_layers, q72.d_ff, q72.vocab_size) == (80, 29568, 152064)
    assert q72.qkv_bias
    rw = get_model_config("rwkv6-3b")
    assert rw.attention_free and rw.d_model == 2560
    jb = get_model_config("jamba-v0.1-52b")
    assert jb.interleave_period == 8
    mixers = [m for m, _ in jb.pattern]
    from repro.configs import BlockKind
    assert mixers.count(BlockKind.ATTENTION) == 1
    assert mixers.count(BlockKind.MAMBA) == 7
