"""End-to-end system tests: trainer loop + auto-resume + SeqPoint hook,
CTC correctness, optimizer behaviour, gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    MeshConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    StepKind,
    smoke_config,
)
from repro.data.batching import DataIterator
from repro.data.synthetic import IWSLT_LIKE
from repro.models import Runtime, build_model
from repro.train.trainer import Trainer


def _tiny_run(arch="starcoder2-3b", **kw):
    cfg = smoke_config(arch).with_overrides(num_layers=2, d_model=64,
                                            d_ff=128, vocab_size=256)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=8,
                        step=StepKind.TRAIN)
    mesh = MeshConfig(shape=(1,), axes=("data",))
    run = RunConfig(model=cfg, shape=shape, mesh=mesh,
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
                    param_dtype="float32", compute_dtype="float32", **kw)
    return cfg, run


def _data(cfg):
    return DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                        vocab_size=cfg.vocab_size, granularity=8, seed=1)


def test_trainer_loss_decreases_and_logs_sls():
    cfg, run = _tiny_run()
    model = build_model(cfg, Runtime.from_run(run))
    tr = Trainer(model, run, _data(cfg), total_steps=40)
    report = tr.train(30)
    assert report.steps == 30
    assert np.mean(report.losses[:5]) > np.mean(report.losses[-5:])
    assert tr.epoch_log.num_iterations == 30
    sp = tr.seqpoints(error_threshold=0.1)
    assert sp.num_points >= 1
    assert np.isclose(sp.weights.sum(), 30)


def test_trainer_resume_bitwise(tmp_path):
    cfg, run = _tiny_run()

    def make_trainer():
        model = build_model(cfg, Runtime.from_run(run))
        return Trainer(model, run, _data(cfg), ckpt_dir=str(tmp_path),
                       ckpt_every=5, total_steps=40)

    # continuous run: 10 steps
    t_full = make_trainer()
    rep_full = t_full.train(10)

    # interrupted run: 5 steps, then a NEW trainer resumes for 5 more
    import shutil
    shutil.rmtree(str(tmp_path))
    t_a = make_trainer()
    t_a.train(5)
    t_b = make_trainer()
    rep_b = t_b.train(5)
    assert rep_b.resumed_from == 5
    np.testing.assert_allclose(rep_full.losses[5:], rep_b.losses,
                               rtol=1e-5, atol=1e-6)


def test_ctc_matches_bruteforce():
    """CTC forward equals explicit path enumeration on a tiny case."""
    from repro.models.rnn import ctc_loss

    rng = jax.random.PRNGKey(0)
    T, V = 4, 3
    logits = jax.random.normal(rng, (1, T, V))
    labels = jnp.array([[1, 2]], jnp.int32)
    lens = jnp.array([2], jnp.int32)
    loss = float(ctc_loss(logits, labels, lens))

    # brute force: sum over all alignments of length T collapsing to [1, 2]
    import itertools
    logp = jax.nn.log_softmax(logits[0], axis=-1)
    total = -np.inf
    for path in itertools.product(range(V), repeat=T):
        collapsed = []
        prev = None
        for s in path:
            if s != prev and s != 0:
                collapsed.append(s)
            prev = s
        if collapsed == [1, 2]:
            lp = sum(float(logp[t, s]) for t, s in enumerate(path))
            total = np.logaddexp(total, lp)
    np.testing.assert_allclose(loss, -total, rtol=1e-5)


def test_adamw_optimizes_quadratic():
    from repro.train.optimizer import adamw_update, init_opt_state, \
        lr_schedule

    cfg = OptimizerConfig(lr=0.1, warmup_steps=0, weight_decay=0.0,
                          grad_clip=0.0)
    params = {"x": jnp.array([5.0, -3.0])}
    state = init_opt_state(params, cfg)
    lr_fn = lr_schedule(cfg, 200)
    for _ in range(150):
        grads = {"x": 2 * params["x"]}
        params, state, m = adamw_update(grads, state, params, cfg,
                                        lr_fn(state.step))
    assert float(jnp.max(jnp.abs(params["x"]))) < 0.1


def test_grad_compression_int8_error_feedback():
    from repro.dist.compression import compress_grads, decompress_grads

    rng = jax.random.PRNGKey(1)
    g = {"w": jax.random.normal(rng, (64, 64))}
    wire, err = compress_grads(g, "int8_ef")
    out = decompress_grads(wire, "int8_ef", g)
    rel = float(jnp.linalg.norm(out["w"] - g["w"])
                / jnp.linalg.norm(g["w"]))
    assert rel < 0.02                     # int8 quantization error bound
    # error feedback accumulates the residual
    assert err is not None
    np.testing.assert_allclose(np.asarray(out["w"] + err["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-6)


def test_serve_engine_does_not_mutate_requests_and_truncates():
    from repro.serve.engine import Request, ServeEngine

    cfg, run = _tiny_run()
    model = build_model(cfg, Runtime.from_run(run))
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=4, max_len=64,
                      sl_granularity=16)
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=3),
            # prompt longer than max_len: must truncate, not crash
            Request(prompt=np.arange(1, 101, dtype=np.int32) % cfg.vocab_size,
                    max_new_tokens=2)]
    out = eng.run_batch(reqs)
    # only the real requests come back; the caller's list is untouched
    assert out is reqs and len(reqs) == 2
    assert len(out[0].output) == 3 and len(out[1].output) == 2
    assert eng.log.num_iterations == 1


def test_serve_decode_call_count_and_latency_logged():
    """n_steps useful tokens must cost exactly n_steps - 1 decode calls
    (prefill supplies the first token), and the serve EpochLog must carry
    decode latency, not prefill only."""
    from repro.serve.engine import Request, ServeEngine

    cfg, run = _tiny_run()
    model = build_model(cfg, Runtime.from_run(run))
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, batch_size=2, max_len=64,
                      sl_granularity=16)
    calls = {"n": 0}
    real_decode = eng._decode

    def counting_decode(*a, **kw):
        calls["n"] += 1
        return real_decode(*a, **kw)

    eng._decode = counting_decode
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=4)]
    eng.run_batch(reqs)
    assert len(reqs[0].output) == 4
    assert calls["n"] == 3                    # n_steps - 1
    rec = eng.log.iterations[-1]
    assert rec.stats["decode_steps"] == 3.0
    assert rec.stats["decode_s"] >= 0.0
    assert "tokens_out" in rec.stats

    # a single-token request needs no decode call at all
    calls["n"] = 0
    eng.run_batch([Request(prompt=np.arange(1, 5, dtype=np.int32),
                           max_new_tokens=1)])
    assert calls["n"] == 0


def test_straggler_counter():
    cfg, run = _tiny_run()
    model = build_model(cfg, Runtime.from_run(run))
    tr = Trainer(model, run, _data(cfg), straggler_factor=1e-9,
                 total_steps=10)
    rep = tr.train(6)
    assert rep.stragglers >= 4            # every step beyond the first few
