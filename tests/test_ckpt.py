"""Checkpoint manager: identity, atomicity, pruning, corruption, async."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
            "stack": (jnp.ones((3, 4)), jnp.zeros((2,)))}


def test_save_restore_identity(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = make_state()
    mgr.save(10, state, extra={"step": 10, "note": "x"})
    like = jax.tree.map(jnp.zeros_like, state)
    restored, extra = mgr.restore(like)
    assert extra["step"] == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state, restored)


def test_keep_last_prunes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, make_state(s))
    assert mgr.steps() == [3, 4]


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, make_state())
    npz = os.path.join(str(tmp_path), "step_00000005", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x01\x02\x03")
    with pytest.raises(IOError):
        mgr.restore(make_state())


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = make_state()
    mgr.save_async(7, state, extra={"step": 7})
    mgr.wait()
    restored, extra = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert extra["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state, restored)


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state())
    bad = {"a": jnp.zeros((4, 4)),
           "nested": {"b": jnp.zeros((10,), jnp.int32)},
           "stack": (jnp.ones((3, 4)), jnp.zeros((2,)))}
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_no_tmp_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, make_state())
    assert not any(n.endswith(".tmp") for n in os.listdir(str(tmp_path)))


def _corrupt(tmp_path, step):
    npz = os.path.join(str(tmp_path), f"step_{step:08d}", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x01\x02\x03")


def test_corrupt_latest_falls_back_one_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state(1), extra={"step": 1})
    mgr.save(2, make_state(2), extra={"step": 2})
    _corrupt(tmp_path, 2)
    restored, extra = mgr.restore(jax.tree.map(jnp.zeros_like, make_state()))
    assert extra["step"] == 1                   # fell back past the damage
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 make_state(1), restored)


def test_truncated_latest_falls_back(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state(1), extra={"step": 1})
    mgr.save(2, make_state(2), extra={"step": 2})
    npz = os.path.join(str(tmp_path), "step_00000002", "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(64)                          # killed writer / bad disk
    _, extra = mgr.restore(make_state())
    assert extra["step"] == 1


def test_explicit_step_is_strict_by_default(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state(1), extra={"step": 1})
    mgr.save(2, make_state(2), extra={"step": 2})
    _corrupt(tmp_path, 2)
    with pytest.raises(IOError):
        mgr.restore(make_state(), step=2)       # pinned: no silent fallback
    _, extra = mgr.restore(make_state(), step=2, fallback=True)
    assert extra["step"] == 1


def test_verify_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state(1))
    assert mgr.verify_step(1)
    _corrupt(tmp_path, 1)
    assert not mgr.verify_step(1)
    assert not mgr.verify_step(99)              # missing step is not valid


def test_async_write_failure_surfaces_at_next_save(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path))
    state = make_state()

    def boom(step, flat, extra):
        raise IOError("disk on fire")

    monkeypatch.setattr(mgr, "_write", boom)
    mgr.save_async(1, state)                    # background failure...
    monkeypatch.undo()
    with pytest.raises(IOError, match="disk on fire"):
        mgr.save(2, state)                      # ...surfaces here
    mgr.save(2, state)                          # error is consumed; works
    assert mgr.latest_step() == 2
