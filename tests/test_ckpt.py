"""Checkpoint manager: identity, atomicity, pruning, corruption, async."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32)},
            "stack": (jnp.ones((3, 4)), jnp.zeros((2,)))}


def test_save_restore_identity(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = make_state()
    mgr.save(10, state, extra={"step": 10, "note": "x"})
    like = jax.tree.map(jnp.zeros_like, state)
    restored, extra = mgr.restore(like)
    assert extra["step"] == 10
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state, restored)


def test_keep_last_prunes(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, make_state(s))
    assert mgr.steps() == [3, 4]


def test_corruption_detected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(5, make_state())
    npz = os.path.join(str(tmp_path), "step_00000005", "arrays.npz")
    with open(npz, "r+b") as f:
        f.seek(100)
        f.write(b"\x00\x01\x02\x03")
    with pytest.raises(IOError):
        mgr.restore(make_state())


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = make_state()
    mgr.save_async(7, state, extra={"step": 7})
    mgr.wait()
    restored, extra = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert extra["step"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 state, restored)


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state())
    bad = {"a": jnp.zeros((4, 4)),
           "nested": {"b": jnp.zeros((10,), jnp.int32)},
           "stack": (jnp.ones((3, 4)), jnp.zeros((2,)))}
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_no_tmp_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(3, make_state())
    assert not any(n.endswith(".tmp") for n in os.listdir(str(tmp_path)))
