import os
import sys

# tests import the package from src/ without installation; do NOT set
# XLA device-count flags here — smoke tests must see 1 device (multi-device
# tests spawn subprocesses, dryrun sets its own flags).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
