"""Hypothesis property tests for the SeqPoint invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import EpochLog, select_seqpoints
from repro.core.seqpoint import _bin_edges, _select_with_k
from repro.data.batching import pad_to, plan_epoch


@st.composite
def epoch_logs(draw):
    n_unique = draw(st.integers(2, 60))
    sls = draw(st.lists(st.integers(1, 2048), min_size=n_unique,
                        max_size=n_unique, unique=True))
    counts = draw(st.lists(st.integers(1, 50), min_size=n_unique,
                           max_size=n_unique))
    a = draw(st.floats(1e-6, 1e-2))
    b = draw(st.floats(1e-6, 1e-1))
    log = EpochLog()
    for sl, c in zip(sls, counts):
        for _ in range(c):
            log.append(sl, a * sl + b)
    return log


@settings(max_examples=40, deadline=None)
@given(epoch_logs())
def test_weights_partition_iterations(log):
    sp = select_seqpoints(log, error_threshold=0.05)
    assert np.isclose(sp.weights.sum(), log.num_iterations)


@settings(max_examples=40, deadline=None)
@given(epoch_logs())
def test_points_are_observed_sls(log):
    sp = select_seqpoints(log, error_threshold=0.05)
    observed = set(int(s) for s in log.seq_lens())
    assert set(sp.seq_lens) <= observed


@settings(max_examples=40, deadline=None)
@given(epoch_logs())
def test_all_unique_exact_when_small(log):
    table = log.by_seq_len()
    sp = select_seqpoints(log, n_threshold=max(10, table.num_unique))
    assert sp.error < 1e-9


@settings(max_examples=30, deadline=None)
@given(epoch_logs(), st.integers(2, 20))
def test_bins_cover_all_sls(log, k):
    table = log.by_seq_len()
    points = _select_with_k(table, k)
    # every iteration is represented by exactly one bin
    assert np.isclose(sum(p.weight for p in points), table.num_iterations)
    edges = _bin_edges(table, k)
    assert edges[0] <= table.seq_lens[0]
    assert edges[-1] > table.seq_lens[-1]


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 500), min_size=32, max_size=400),
       st.sampled_from([8, 16, 32]), st.sampled_from([1, 4, 8]))
def test_batch_plan_invariants(sls, batch, gran):
    plan = plan_epoch(np.array(sls), batch, granularity=gran)
    # padded SL is a granularity multiple and >= every member
    for p, members in zip(plan.padded_sls, plan.member_sls):
        assert p % gran == 0
        assert p >= members.max()
        assert p - pad_to(int(members.max()), gran) == 0
    assert 0.0 <= plan.padding_waste() < 1.0


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(1, 500), min_size=64, max_size=400))
def test_bucketed_batching_never_increases_padding(sls):
    sls = np.array(sls)
    rand = plan_epoch(sls, 16, granularity=1, bucketed=False, seed=3)
    buck = plan_epoch(sls, 16, granularity=1, bucketed=True, seed=3)
    assert buck.padding_waste() <= rand.padding_waste() + 1e-9
