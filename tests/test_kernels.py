"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.lstm_cell.kernel import lstm_cell_fwd
from repro.kernels.lstm_cell.ref import lstm_cell_ref
from repro.kernels.mamba_scan.kernel import mamba_scan_fwd
from repro.kernels.mamba_scan.ref import mamba_scan_ref
from repro.kernels.rwkv6_wkv.kernel import wkv6_fwd
from repro.kernels.rwkv6_wkv.ref import wkv6_ref

RNG = jax.random.PRNGKey(0)


@pytest.mark.parametrize("bh,bhkv,sq,skv,dh,causal", [
    (2, 2, 128, 128, 64, True),
    (4, 2, 256, 256, 64, True),
    (4, 1, 128, 256, 128, False),
    (8, 4, 384, 384, 64, True),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(bh, bhkv, sq, skv, dh, causal, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (bh, sq, dh), dtype)
    k = jax.random.normal(ks[1], (bhkv, skv, dh), dtype)
    v = jax.random.normal(ks[2], (bhkv, skv, dh), dtype)
    out = flash_attention_fwd(q, k, v, causal=causal, block_q=128,
                              block_k=128, interpret=True)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 2e-3 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("bh,s,dh,chunk", [
    (2, 128, 64, 32), (4, 256, 64, 64), (2, 64, 32, 64), (3, 192, 64, 64),
])
def test_wkv6(bh, s, dh, chunk):
    ks = jax.random.split(RNG, 5)
    r, k, v = (jax.random.normal(ks[i], (bh, s, dh)) for i in range(3))
    lw = -jnp.exp(jnp.clip(jax.random.normal(ks[3], (bh, s, dh)), -8, 0))
    u = jax.random.normal(ks[4], (bh, dh))
    y = wkv6_fwd(r, k, v, lw, u, chunk=chunk, interpret=True)
    ref = wkv6_ref(r, k, v, lw, u)
    np.testing.assert_allclose(y, ref, rtol=5e-4, atol=5e-4)


@pytest.mark.parametrize("b,s,d,n,bd,chunk", [
    (2, 128, 128, 8, 128, 32), (1, 64, 256, 16, 128, 64),
    (2, 96, 64, 4, 64, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mamba_scan(b, s, d, n, bd, chunk, dtype):
    ks = jax.random.split(RNG, 6)
    x = jax.random.normal(ks[0], (b, s, d), dtype)
    delta = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)) - 2).astype(
        dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    bm = jax.random.normal(ks[3], (b, s, n), dtype)
    cm = jax.random.normal(ks[4], (b, s, n), dtype)
    dd = jax.random.normal(ks[5], (d,))
    y = mamba_scan_fwd(x, delta, a, bm, cm, dd, block_d=bd, chunk=chunk,
                       interpret=True)
    ref = mamba_scan_ref(x, delta, a, bm, cm, dd)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(y.astype(jnp.float32),
                               ref.astype(jnp.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("b,d,h,bb,bhid", [
    (64, 96, 128, 64, 64), (128, 128, 128, 128, 128), (32, 64, 256, 32, 128),
])
def test_lstm_cell(b, d, h, bb, bhid):
    ks = jax.random.split(RNG, 4)
    xh = jax.random.normal(ks[0], (b, d + h))
    w = jax.random.normal(ks[1], (d + h, h, 4)) * 0.1
    bias = jax.random.normal(ks[2], (h, 4)) * 0.1
    c = jax.random.normal(ks[3], (b, h))
    h1, c1 = lstm_cell_fwd(xh, w, bias, c, block_b=bb, block_h=bhid,
                           interpret=True)
    h2, c2 = lstm_cell_ref(xh, w, bias, c)
    np.testing.assert_allclose(h1, h2, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(c1, c2, rtol=3e-5, atol=3e-5)


def test_flash_attention_vjp_matches_ref():
    from repro.kernels.flash_attention.ops import flash_attention

    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))

    def f(q, k, v):
        return jnp.sum(flash_attention(q, k, v) ** 2)

    g = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for t in g:
        assert bool(jnp.all(jnp.isfinite(t)))


def test_wkv6_matches_model_chunked_path():
    """Kernel agrees with the model's own chunked formulation."""
    from repro.models.rwkv import wkv6_chunked

    ks = jax.random.split(RNG, 5)
    b, s, h, dh = 2, 128, 2, 32
    r, k, v = (jax.random.normal(ks[i], (b, s, h, dh)) for i in range(3))
    lw = -jnp.exp(jnp.clip(jax.random.normal(ks[3], (b, s, h, dh)), -8, 0))
    u = jax.random.normal(ks[4], (h, dh))
    y_model, _ = wkv6_chunked(r, k, v, lw, u,
                              jnp.zeros((b, h, dh, dh)), chunk=32)
    from repro.kernels.rwkv6_wkv.ops import wkv6 as wkv6_op
    y_kernel = wkv6_op(r, k, v, lw, u, 32)
    np.testing.assert_allclose(y_kernel, y_model, rtol=5e-4, atol=5e-4)
