"""repro.serve.sched: SL-bucketed queues, admission policies, and the
continuous-batching loop — including the acceptance comparison against the
run-to-completion baseline and the determinism contract."""
import jax
import numpy as np
import pytest

from repro import obs
from repro.configs import (
    MeshConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    StepKind,
    smoke_config,
)
from repro.models import Runtime, build_model
from repro.resilience import faults
from repro.resilience.faults import FaultPlan
from repro.resilience.recovery import RecoveryPolicy
from repro.serve.engine import Request, ServeEngine
from repro.serve.sched import (
    AdmissionQueue,
    BucketAffinePolicy,
    FifoPolicy,
    SeqPointPolicy,
    run_to_completion,
    sl_bucket,
)


class FakeClock:
    """One tick per call: latencies/TTFTs are bit-identical across runs."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


@pytest.fixture(scope="module")
def model_and_params():
    cfg = smoke_config("starcoder2-3b").with_overrides(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=8,
                        step=StepKind.TRAIN)
    run = RunConfig(model=cfg, shape=shape,
                    mesh=MeshConfig(shape=(1,), axes=("data",)),
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
                    param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg, Runtime.from_run(run))
    return model, model.init(jax.random.PRNGKey(0))


def _engine(model_and_params, **kw):
    model, params = model_and_params
    kw.setdefault("batch_size", 4)
    kw.setdefault("max_len", 160)
    kw.setdefault("sl_granularity", 8)
    kw.setdefault("policy", RecoveryPolicy(backoff_base_s=0.0))
    return ServeEngine(model, params, **kw)


def _requests(seed=0, n=16, wide_every=4, wide_sl=128):
    """Skewed-SL stream: mostly short prompts with a wide straggler every
    ``wide_every``-th request — the FIFO-batching worst case, since every
    arrival-order chunk pads to the straggler's width."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n):
        sl = wide_sl if i % wide_every == 0 else int(rng.randint(5, 9))
        reqs.append(Request(
            prompt=rng.randint(1, 255, size=sl).astype(np.int32),
            max_new_tokens=int(rng.randint(2, 6))))
    return reqs


# --------------------------------------------------------------- queue unit


def test_sl_buckets_match_obs_geometry():
    assert [sl_bucket(s) for s in (1, 2, 3, 8, 9, 128, 129)] == \
        [1, 2, 4, 8, 16, 128, 256]
    assert sl_bucket(7) == obs.bucket_bound(7)


def test_admission_queue_fifo_buckets_and_eligibility():
    q = AdmissionQueue(max_len=128, timer=FakeClock())
    reqs = [Request(prompt=np.ones(s, np.int32), max_new_tokens=m)
            for s, m in ((5, 4), (60, 4), (5, 4), (200, 4), (60, 200))]
    tickets = [q.submit(r) for r in reqs]
    assert [t.seq for t in q.pending()] == [0, 1, 2, 3, 4]
    assert q.buckets() == [8, 64, 128]       # 200 capped at max_len
    assert q.depth(8) == 2 and q.depth() == 5
    assert tickets[3].padded == 128          # prompt > max_len is capped
    # position constraint: only prompts fitting under pos=16 are eligible
    assert [t.seq for t in q.eligible(pos=16)] == [0, 2]
    # budget constraint: decode tail must fit before max_len
    assert [t.seq for t in q.eligible(budget=10)] == [0, 1, 2, 3]
    q.take([tickets[0]])
    assert q.depth(8) == 1 and q.oldest().seq == 1


def test_admission_queue_sheds_on_bounded_depth():
    q = AdmissionQueue(max_len=64, timer=FakeClock(), max_depth=2)
    reqs = [Request(prompt=np.ones(4, np.int32)) for _ in range(3)]
    assert q.submit(reqs[0]) is not None
    assert q.submit(reqs[1]) is not None
    assert q.submit(reqs[2]) is None
    assert reqs[2].shed and not reqs[0].shed
    assert q.shed == 1 and q.depth() == 2


# ------------------------------------------------------------- policy unit


def _tickets(sls, max_new=4):
    q = AdmissionQueue(max_len=512, timer=FakeClock())
    for s in sls:
        q.submit(Request(prompt=np.ones(s, np.int32),
                         max_new_tokens=max_new))
    return q.pending()


def test_fifo_policy_is_arrival_order():
    ts = _tickets([256, 8, 8, 8])
    assert [t.seq for t in FifoPolicy().select(ts, 2)] == [0, 1]


def test_bucket_affine_packs_anchor_bucket_first():
    # oldest (seq 0, bucket 8) anchors; same-bucket seq 2/3 beat the
    # wide seq 1 even though it arrived earlier
    ts = _tickets([8, 256, 8, 8])
    picked = BucketAffinePolicy().select(ts, 3)
    assert [t.seq for t in picked] == [0, 2, 3]
    # aging beats packing: once the wide one is oldest, it is admitted
    picked = BucketAffinePolicy().select(ts[1:], 2)
    assert picked[0].seq == 1


def test_seqpoint_policy_maximizes_useful_compute():
    cost = lambda sl: float(sl)                       # noqa: E731
    ts = _tickets([8, 512, 8, 8, 8])
    # packing the four SL-8s at width 8 is 100% useful; any set containing
    # the 512 scores at most (512+3*8)/(4*512)
    picked = SeqPointPolicy(cost).select(ts, 4)
    assert [t.seq for t in picked] == [0, 2, 3, 4]
    # the anchor is always admitted, even when it scores terribly
    picked = SeqPointPolicy(cost).select(ts[1:2], 4)
    assert [t.seq for t in picked] == [1]


# ----------------------------------------------------- acceptance criteria


def test_sched_beats_run_to_completion_on_skewed_sls(model_and_params):
    """Zipf-skewed SLs through the continuous-batching scheduler: >= 25%
    lower padding waste and strictly higher token throughput than the
    run-to-completion run_batch baseline, with identical tokens served."""
    base_eng = _engine(model_and_params)
    base = run_to_completion(base_eng, _requests(seed=0))

    eng = _engine(model_and_params)
    reqs = _requests(seed=0)
    stats = eng.serve(reqs, policy=BucketAffinePolicy())

    assert stats.n_finished == stats.n_requests == 16
    assert stats.n_curtailed == 0 and stats.n_shed == 0
    assert all(len(r.output) == r.max_new_tokens and not r.curtailed
               for r in reqs)
    assert stats.tokens_out == base.tokens_out      # same service delivered
    # >= 25% padding-waste reduction on the padded-grid compute proxy
    assert stats.padding_waste <= 0.75 * base.padding_waste, \
        (stats.padding_waste, base.padding_waste)
    # strictly higher token throughput per unit padded compute
    assert stats.grid_throughput > base.grid_throughput
    # the obs gauge agrees with the stats object
    assert obs.metrics.gauge("serve_sched_padding_waste").value == \
        pytest.approx(stats.padding_waste)


def test_seqpoint_policy_no_worse_than_fifo_on_skewed_sls(model_and_params):
    fifo_eng = _engine(model_and_params)
    fifo = fifo_eng.serve(_requests(seed=3), policy=FifoPolicy())
    sp_eng = _engine(model_and_params)
    sp = sp_eng.serve(_requests(seed=3),
                      policy=SeqPointPolicy(lambda sl: float(sl)))
    assert sp.tokens_out == fifo.tokens_out
    # the cost model discovers the wide-with-wide grouping FIFO misses
    assert sp.padding_waste < fifo.padding_waste
    assert sp.grid_throughput > fifo.grid_throughput


# ----------------------------------------------------------- determinism


def _deterministic_run(model_and_params, spec):
    faults.install(FaultPlan.parse(spec, seed=0) if spec else None)
    try:
        obs.metrics.reset()
        eng = _engine(model_and_params, timer=FakeClock(), n_replicas=2,
                      hedge_factor=3.0)
        reqs = _requests(seed=1, n=12)
        stats = eng.serve(reqs, policy=BucketAffinePolicy())
        sched_metrics = {
            name: rows for name, rows in obs.metrics.snapshot().items()
            if name.startswith("serve_")}
        return (stats.admission_order,
                [list(r.output) for r in reqs],
                [r.curtailed for r in reqs],
                stats.summary(), sched_metrics)
    finally:
        faults.install(None)
        obs.metrics.reset()


def test_sched_is_deterministic_under_faults(model_and_params):
    """Same request set + same REPRO_FAULTS spec => identical admission
    order, per-request tokens, and per-bucket metrics across two runs
    (FakeClock: no wall-clock dependence anywhere)."""
    spec = "decode@3,peer_slow@2:delay=9.0"
    a = _deterministic_run(model_and_params, spec)
    b = _deterministic_run(model_and_params, spec)
    assert a[0] == b[0]                              # admission order
    assert a[1] == b[1]                              # token streams
    assert a[2] == b[2]                              # curtailment flags
    assert a[3] == b[3]                              # stats incl. wall_s
    assert a[4] == b[4]                              # per-bucket metrics
    assert a[3]["tokens_out"] > 0


# ------------------------------------------- deadlines, curtailment, drain


def test_run_batch_deadline_records_curtailed_flag(model_and_params):
    """Satellite regression: a request cut by deadline_s mid-decode is
    distinguishable from a completed one in the serve EpochLog."""
    eng = _engine(model_and_params, deadline_s=0.0)
    cut = Request(prompt=np.arange(1, 9, dtype=np.int32), max_new_tokens=8)
    eng.run_batch([cut])
    assert cut.curtailed and 0 < len(cut.output) < cut.max_new_tokens
    assert eng.log.iterations[-1].stats["curtailed"] == 1.0

    done = Request(prompt=np.arange(1, 9, dtype=np.int32),
                   max_new_tokens=1)
    eng.run_batch([done])                 # token comes straight from prefill
    assert not done.curtailed and len(done.output) == 1
    assert eng.log.iterations[-1].stats["curtailed"] == 0.0


def test_sched_deadline_curtails_with_flag(model_and_params):
    clock = FakeClock()
    eng = _engine(model_and_params, timer=clock, deadline_s=8.0)
    reqs = [Request(prompt=np.arange(1, 17, dtype=np.int32),
                    max_new_tokens=500) for _ in range(2)]
    stats = eng.serve(reqs, policy=FifoPolicy())
    assert stats.n_curtailed == len(reqs)
    for r in reqs:
        assert r.curtailed and 0 < len(r.output) < r.max_new_tokens
    recs = eng.log.iterations[-2:]
    assert all(rec.stats["curtailed"] == 1.0 for rec in recs)
    assert stats.n_finished == len(reqs)             # slots freed, drained


def test_sched_fresh_wave_admits_wide_request_after_drain(model_and_params):
    """A prompt wider than the live position can't splice mid-stream; it
    is admitted by the fresh wave once the engine drains."""
    eng = _engine(model_and_params)
    narrow = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                      max_new_tokens=3) for _ in range(4)]
    wide = Request(prompt=np.arange(1, 129, dtype=np.int32),
                   max_new_tokens=3)
    stats = eng.serve(narrow + [wide], policy=BucketAffinePolicy())
    assert stats.n_finished == 5 and stats.n_curtailed == 0
    assert len(wide.output) == 3 and not wide.curtailed
    assert stats.prefills >= 2                        # splice or re-wave


def test_sched_log_is_seqpoint_summarizable(model_and_params):
    eng = _engine(model_and_params)
    eng.serve(_requests(seed=2, n=12), policy=BucketAffinePolicy())
    assert eng.log.num_iterations == 12
    rec = eng.log.iterations[0]
    for key in ("tokens_out", "ttft_s", "queue_wait_s", "curtailed"):
        assert key in rec.stats
    sp = eng.seqpoints(error_threshold=0.5, n_threshold=8)
    assert sp.num_points >= 1


def test_sched_sheds_on_bounded_queue(model_and_params):
    eng = _engine(model_and_params)
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=2) for _ in range(6)]
    stats = eng.serve(reqs, max_queue=4)
    assert stats.n_shed == 2
    assert [r.shed for r in reqs] == [False] * 4 + [True] * 2
    assert all(r.output == [] for r in reqs[4:])      # safe to resubmit
    assert stats.n_finished == 4
