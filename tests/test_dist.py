"""repro.dist unit tests: logical-axis scoping, compression round-trips,
error-feedback training parity, and collective-bytes accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    MeshConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    StepKind,
    smoke_config,
)
from repro.data.batching import DataIterator
from repro.data.synthetic import IWSLT_LIKE
from repro.dist import axes as dist_axes
from repro.dist.axes import _resolve, constrain, current_mesh_axes, \
    set_dp_axes
from repro.dist.compression import (
    METHODS,
    compress_grads,
    decompress_grads,
    dp_grad_wire_bytes,
    init_residual,
    uses_error_feedback,
)
from repro.dist.sharding import tp_activation_wire_bytes
from repro.models import Runtime, build_model
from repro.train.trainer import Trainer


# ---------------------------------------------------------------------------
# axes


def test_resolve_defaults():
    mesh_axes = ("pod", "data", "model")
    assert _resolve("dp", mesh_axes) == ("pod", "data")
    assert _resolve("tp", mesh_axes) == ("model",)
    assert _resolve("ep", mesh_axes) == ("data", "model")
    assert _resolve(None, mesh_axes) == ()
    # unknown names pass through as physical axis names
    assert _resolve("data", mesh_axes) == ("data",)
    assert _resolve("nonexistent", mesh_axes) == ()
    # filtered to the axes actually on the mesh
    assert _resolve("dp", ("data", "model")) == ("data",)


def test_set_dp_axes_scoping_restores():
    assert dist_axes.dp_axes() == ("pod", "data")
    with set_dp_axes(("pod", "data", "model")):
        assert _resolve("dp", ("pod", "data", "model")) == \
            ("pod", "data", "model")
        with set_dp_axes(("data",)):
            assert dist_axes.dp_axes() == ("data",)
        assert dist_axes.dp_axes() == ("pod", "data", "model")
    assert dist_axes.dp_axes() == ("pod", "data")
    # plain-call form (no context manager): sticky until reset
    set_dp_axes(("data",))
    assert dist_axes.dp_axes() == ("data",)
    set_dp_axes(None)
    assert dist_axes.dp_axes() == ("pod", "data")


def test_constrain_no_mesh_is_identity():
    assert current_mesh_axes() == ()
    x = jnp.ones((4, 8))
    # no mesh: identity, and no rank validation is attempted
    assert constrain(x, "dp", "tp") is x
    assert constrain(x, "dp") is x


def test_constrain_under_mesh_validates_and_guards():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    x = jnp.ones((4, 8))
    with mesh:
        assert current_mesh_axes() == ("data",)
        with pytest.raises(ValueError):
            constrain(x, "dp")                 # rank mismatch
        # extent-1 axes leave the array unconstrained (identity)
        assert constrain(x, "dp", "tp") is x


# ---------------------------------------------------------------------------
# compression


def _grad_tree(seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {"a": jax.random.normal(ks[0], (32, 48)),
            "b": {"c": jax.random.normal(ks[1], (128,)) * 10.0,
                  "d": jax.random.normal(ks[2], (8, 4, 4)) * 0.01}}


@pytest.mark.parametrize("method", METHODS)
def test_roundtrip_plus_residual_reconstructs(method):
    g = _grad_tree()
    wire, err = compress_grads(g, method)
    out = decompress_grads(wire, method, g)
    assert jax.tree.structure(out) == jax.tree.structure(g)
    if method == "none":
        assert err is None
        recon = out
    else:
        assert err is not None
        recon = jax.tree.map(jnp.add, out, err)
    for k in jax.tree.leaves(jax.tree.map(
            lambda r, o: np.max(np.abs(np.asarray(r) - np.asarray(o))),
            recon, g)):
        assert k < 1e-5


@pytest.mark.parametrize("method,bound", [("bf16", 0.005), ("int8_ef", 0.02)])
def test_roundtrip_relative_error_bound(method, bound):
    g = _grad_tree(1)
    wire, _ = compress_grads(g, method)
    out = decompress_grads(wire, method, g)
    for o, gg in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
        rel = float(jnp.linalg.norm(o.astype(jnp.float32) - gg)
                    / jnp.linalg.norm(gg))
        assert rel < bound


def test_topk_keeps_largest_exactly():
    g = {"w": jnp.asarray(np.linspace(-1.0, 1.0, 100, dtype=np.float32))}
    wire, err = compress_grads(g, "topk_ef")
    out = decompress_grads(wire, "topk_ef", g)
    kept = np.flatnonzero(np.asarray(out["w"]))
    # 5% of 100 = 5 entries, the largest by magnitude, kept exactly
    assert len(kept) == 5
    np.testing.assert_allclose(np.asarray(out["w"])[kept],
                               np.asarray(g["w"])[kept], rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(out["w"] + err["w"]),
                               np.asarray(g["w"]), rtol=1e-6, atol=1e-7)


def test_init_residual_and_method_check():
    p = {"w": jnp.ones((3, 3))}
    assert init_residual(p, "none") is None
    assert init_residual(p, "bf16") is None
    ef = init_residual(p, "int8_ef")
    assert float(jnp.abs(ef["w"]).max()) == 0.0
    assert uses_error_feedback("topk_ef")
    assert not uses_error_feedback("bf16")
    with pytest.raises(ValueError):
        compress_grads(p, "fp4")


def test_compression_is_jittable():
    g = _grad_tree(2)

    @jax.jit
    def f(g):
        wire, err = compress_grads(g, "int8_ef")
        return decompress_grads(wire, "int8_ef", g), err

    out, err = f(g)
    np.testing.assert_allclose(
        np.asarray(out["a"] + err["a"]), np.asarray(g["a"]),
        rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# wire accounting


def test_dp_grad_wire_bytes_scaling():
    p = {"w": jnp.zeros((1000,), jnp.float32)}
    assert dp_grad_wire_bytes(p, "none", 1) == 0.0
    full = dp_grad_wire_bytes(p, "none", 4)
    assert full == pytest.approx(2 * 3 / 4 * 4000)     # ring factor x f32
    assert dp_grad_wire_bytes(p, "int8_ef", 4) == pytest.approx(full / 4)
    assert dp_grad_wire_bytes(p, "bf16", 4) == pytest.approx(full / 2)


def test_dp_grad_wire_bytes_grad_dtype_and_micro_reduces():
    from repro.dist.compression import wire_bytes_per_elem

    p = {"w": jnp.zeros((1000,), jnp.float32)}
    full = dp_grad_wire_bytes(p, "none", 4)
    # uncompressed bf16 grads are half the wire width of f32 grads
    assert dp_grad_wire_bytes(p, "none", 4, grad_dtype_bytes=2.0) \
        == pytest.approx(full / 2)
    # compressed methods fix their own wire format: native width irrelevant
    assert dp_grad_wire_bytes(p, "int8_ef", 4, grad_dtype_bytes=2.0) \
        == pytest.approx(full / 4)
    # ZeRO-3 reduce-scatters every microbatch
    assert dp_grad_wire_bytes(p, "none", 4, micro_reduces=4) \
        == pytest.approx(4 * full)
    assert wire_bytes_per_elem("none", 2.0) == 2.0
    assert wire_bytes_per_elem("bf16", 2.0) == 2.0
    assert wire_bytes_per_elem("int8_ef", 2.0) == 1.0


def test_tp_wire_bytes_proportional_to_sl():
    cfg = smoke_config("starcoder2-3b")
    b1 = tp_activation_wire_bytes(cfg, 8, 1024, 4)
    b2 = tp_activation_wire_bytes(cfg, 8, 2048, 4)
    assert b2 == pytest.approx(2 * b1)
    assert tp_activation_wire_bytes(cfg, 8, 1024, 1) == 0.0


# ---------------------------------------------------------------------------
# error-feedback training parity (ISSUE 6 acceptance: compressed loss curve
# tracks the uncompressed one on the quickstart config)


def _tiny_run(**kw):
    cfg = smoke_config("starcoder2-3b").with_overrides(
        num_layers=2, d_model=64, d_ff=128, vocab_size=256)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=8,
                        step=StepKind.TRAIN)
    mesh = MeshConfig(shape=(1,), axes=("data",))
    run = RunConfig(model=cfg, shape=shape, mesh=mesh,
                    param_dtype="float32", compute_dtype="float32", **kw)
    return cfg, run


def _losses(grad_compression, steps=25):
    cfg, run = _tiny_run(optimizer=OptimizerConfig(
        lr=1e-3, warmup_steps=2, grad_compression=grad_compression))
    model = build_model(cfg, Runtime.from_run(run))
    data = DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                        vocab_size=cfg.vocab_size, granularity=8, seed=1)
    tr = Trainer(model, run, data, total_steps=steps + 5)
    return tr.train(steps), tr


def test_ef_compressed_curve_tracks_uncompressed():
    rep_u, _ = _losses("none")
    rep_c, tr = _losses("int8_ef")
    # both decrease
    assert np.mean(rep_c.losses[-5:]) < np.mean(rep_c.losses[:5])
    # compressed tracks uncompressed within a few percent at every step
    u, c = np.asarray(rep_u.losses), np.asarray(rep_c.losses)
    assert np.max(np.abs(u - c) / u) < 0.05
    # collective-bytes stats surfaced per iteration into EpochLog
    it = tr.epoch_log.iterations[0]
    assert "dp_wire_bytes" in it.stats and "tp_wire_bytes" in it.stats
    assert tr.epoch_log.total_stat("dp_wire_bytes") == 0.0   # 1-device mesh
