"""SeqPoint algorithm unit tests (paper §V-C semantics)."""
import numpy as np
import pytest

from repro.core import (
    EpochLog,
    frequent,
    kmeans_seqpoints,
    median,
    prior,
    select_seqpoints,
    worst,
)


def make_log(sls, runtime_fn, noise=0.0, seed=0):
    rng = np.random.RandomState(seed)
    log = EpochLog()
    for sl in sls:
        rt = runtime_fn(sl) * (1 + noise * rng.randn())
        log.append(int(sl), max(rt, 1e-9))
    return log


def linear_rt(sl):
    return 1e-3 * sl + 5e-3


def test_all_unique_mode_is_exact():
    sls = [8, 16, 24, 32] * 25
    log = make_log(sls, linear_rt)
    sp = select_seqpoints(log, n_threshold=10)
    assert sp.k == 0 and sp.num_points == 4
    assert sp.error < 1e-9
    assert sp.meta["converged"] is True      # no .get-with-guessed-default
    # weights = frequencies
    assert sorted(p.weight for p in sp.points) == [25.0] * 4


def test_weights_sum_to_iterations():
    rng = np.random.RandomState(1)
    sls = rng.randint(4, 400, size=500)
    log = make_log(sls, linear_rt)
    sp = select_seqpoints(log, error_threshold=0.05)
    assert np.isclose(sp.weights.sum(), log.num_iterations)


def test_representative_is_member_of_its_bin():
    rng = np.random.RandomState(2)
    sls = rng.randint(4, 400, size=800)
    log = make_log(sls, lambda s: 1e-5 * s ** 1.5 + 1e-3)
    sp = select_seqpoints(log, error_threshold=0.01)
    table = log.by_seq_len()
    assert set(sp.seq_lens) <= set(int(s) for s in table.seq_lens)


def test_k_search_reaches_threshold_on_smooth_runtimes():
    rng = np.random.RandomState(3)
    sls = rng.randint(4, 1000, size=2000)
    log = make_log(sls, linear_rt)
    sp = select_seqpoints(log, error_threshold=0.02)
    assert sp.error <= 0.02
    assert sp.num_points <= 40
    assert sp.meta["converged"] is True      # binned success path


def test_projection_to_other_config_scales():
    """SeqPoints selected on config1 must project a 2x-slower config
    exactly when the slowdown is SL-independent (paper architecture-
    independence in the trivial limit)."""
    rng = np.random.RandomState(4)
    sls = rng.randint(4, 300, size=600)
    log = make_log(sls, linear_rt)
    sp = select_seqpoints(log, error_threshold=0.02)
    pred2 = sp.project_total(lambda s: 2 * linear_rt(s))
    actual2 = 2 * sum(linear_rt(s) for s in log.seq_lens())
    assert abs(pred2 - actual2) / actual2 < 0.03


def test_superlinear_runtime_needs_more_bins():
    """Attention-style S^2 runtimes: binning still converges (DESIGN.md §7)."""
    rng = np.random.RandomState(5)
    sls = rng.randint(64, 4096, size=1500)
    log = make_log(sls, lambda s: 1e-9 * s ** 2 + 1e-4)
    sp = select_seqpoints(log, error_threshold=0.02)
    assert sp.error <= 0.02


def test_baselines_shapes():
    rng = np.random.RandomState(6)
    sls = rng.randint(4, 200, size=400)
    log = make_log(sls, linear_rt, noise=0.0)
    f, m, w, p = frequent(log), median(log), worst(log), prior(log)
    assert f.num_points == m.num_points == w.num_points == 1
    assert p.num_points == 50
    # worst bounds the single-iteration strategies by construction
    assert w.error >= f.error - 1e-12
    assert w.error >= m.error - 1e-12
    table = log.by_seq_len()
    assert f.points[0].seq_len == int(
        table.seq_lens[np.argmax(table.counts)])


def test_kmeans_comparable_to_binning():
    """Paper §VII-C: simple binning performs as well as k-means."""
    rng = np.random.RandomState(7)
    sls = rng.randint(4, 500, size=1000)
    log = make_log(sls, linear_rt)
    sp = select_seqpoints(log, error_threshold=0.02)
    km = kmeans_seqpoints(log, k=sp.num_points)
    assert km.error < 0.1


def test_all_unique_mode_at_exactly_threshold():
    """num_unique == n_threshold must still take the exact all-unique path
    (the binned path only starts strictly above the threshold)."""
    sls = list(range(8, 88, 8)) * 3          # exactly 10 unique SLs
    log = make_log(sls, linear_rt)
    sp = select_seqpoints(log, n_threshold=10)
    assert sp.k == 0
    assert sp.meta["mode"] == "all-unique"
    assert sp.num_points == 10
    assert sp.error < 1e-9
    # one more unique SL tips it into binned mode
    log.append(1000, linear_rt(1000))
    sp2 = select_seqpoints(log, n_threshold=10)
    assert sp2.k > 0


def test_empty_bins_are_skipped():
    """SLs clustered at the extremes leave interior bins empty; those bins
    produce no SeqPoint but the weights still cover every iteration."""
    from repro.core.seqpoint import _select_with_k

    sls = [8, 9, 10] * 20 + [990, 1000] * 30
    log = make_log(sls, linear_rt)
    table = log.by_seq_len()
    points = _select_with_k(table, 8)
    assert 0 < len(points) < 8               # interior bins were empty
    assert sum(p.weight for p in points) == len(sls)


def test_non_convergence_sets_meta_flag():
    """Incoherent runtimes (no SL->runtime relation) cannot meet a ~0 error
    threshold; the search must stop at k_max, return the best k found, and
    flag non-convergence."""
    rng = np.random.RandomState(8)
    log = EpochLog()
    for sl in rng.randint(4, 2000, size=300):
        log.append(int(sl), float(rng.uniform(0.5, 1.5)))
    sp = select_seqpoints(log, error_threshold=1e-12, k_max=8)
    assert sp.meta.get("converged") is False
    assert sp.k <= 8
    assert sp.error > 1e-12


def test_sltable_runtime_of_absent_sl_raises():
    log = make_log([8, 16, 32], linear_rt)
    table = log.by_seq_len()
    assert table.runtime_of(16) > 0
    with pytest.raises(KeyError):
        table.runtime_of(24)                 # interior, absent
    with pytest.raises(KeyError):
        table.runtime_of(64)                 # beyond the last SL


def test_skewed_distribution_frequent_fails():
    """The paper's motivating observation: `frequent` can be far off when
    the mode is unrepresentative of total time."""
    sls = [10] * 900 + [1000] * 100
    log = make_log(sls, linear_rt)
    f = frequent(log)
    sp = select_seqpoints(log, error_threshold=0.02)
    assert f.error > 0.3
    assert sp.error <= 0.02
