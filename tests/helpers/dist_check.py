"""Subprocess helper: verify sharded execution matches single-device math.

Runs a tiny model on an 8-device (2 data x 4 model) host mesh, executing a
REAL train-loss computation with the production sharding rules, and compares
against the unsharded result. Exercises the shard_map MoE path end to end.
Prints MATCH <loss> on success.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..",
                                "src"))

from repro.configs import MeshConfig, smoke_config
from repro.dist.sharding import batch_specs, param_specs
from repro.launch.mesh import make_mesh
from repro.models import Runtime, build_model
from repro.configs.base import ShapeConfig, StepKind

ARCH = sys.argv[1] if len(sys.argv) > 1 else "qwen2-moe-a2.7b"

mesh_cfg = MeshConfig(shape=(2, 4), axes=("data", "model"))
cfg = smoke_config(ARCH).with_overrides(vocab_size=512)
B, S = 4, 32
shape = ShapeConfig("tiny", seq_len=S, global_batch=B, step=StepKind.TRAIN)

# single-device reference
model_ref = build_model(cfg, Runtime())
params = model_ref.init(jax.random.PRNGKey(0))
rng = jax.random.PRNGKey(7)
batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
if cfg.frontend == "image_patches":
    batch["patches"] = jax.random.normal(rng, (B, 8, cfg.d_model))
if cfg.frontend == "audio_frames":
    batch["frames"] = jax.random.normal(
        rng, (B, cfg.encoder.max_source_len, cfg.d_model))
loss_ref = jax.jit(lambda p, b: model_ref.loss(p, b)[0])(params, batch)

# sharded run with the production rules
mesh = make_mesh(mesh_cfg)
model_sh = build_model(cfg, Runtime(tp_degree=mesh_cfg.model_degree))
params_sh = model_sh.init(jax.random.PRNGKey(0))
pspecs = param_specs(jax.eval_shape(lambda: params_sh), cfg, mesh_cfg)
bspecs = batch_specs(jax.eval_shape(lambda: batch), mesh_cfg, shape)
params_put = jax.tree.map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), params_sh,
    pspecs)
batch_put = jax.tree.map(
    lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), batch, bspecs)
with mesh:
    loss_sh = jax.jit(lambda p, b: model_sh.loss(p, b)[0])(
        params_put, batch_put)

ok = abs(float(loss_ref) - float(loss_sh)) < 2e-2 * max(
    1.0, abs(float(loss_ref)))
# NOTE: rwkv/starcoder pad heads under tp=4 -> params differ from the
# unsharded model; for those archs we only check finiteness.
import numpy as np

padded = ARCH.startswith("rwkv") or cfg.num_heads % 4 != 0
if padded:
    ok = bool(np.isfinite(float(loss_sh)))
print(("MATCH" if ok else "MISMATCH"),
      float(loss_ref), float(loss_sh), flush=True)
sys.exit(0 if ok else 1)
