"""repro.resilience: fault plans, guards, retries, and trainer chaos paths
(rollback on NaN, preemption + resume parity, corrupt-checkpoint fallback,
serve deadlines/shedding)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    MeshConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    StepKind,
    smoke_config,
)
from repro.data.batching import DataIterator
from repro.data.synthetic import IWSLT_LIKE
from repro.models import Runtime, build_model
from repro.resilience import (
    BatchSkipList,
    DivergenceDetector,
    DivergenceError,
    FaultPlan,
    FaultSpec,
    NonFiniteLossError,
    PreemptionFault,
    RecoveryPolicy,
    StepTimeWatchdog,
    TransientFault,
    check_finite,
    faults,
    retry_with_backoff,
)
from repro.train.trainer import Trainer


@pytest.fixture(autouse=True)
def _no_global_faults():
    """Each test owns the global plan; none leaks to the next test."""
    prev = faults.install(None)
    yield
    faults.install(prev)


# -------------------------------------------------------------------------
# fault plans


def test_fault_spec_parsing():
    s = FaultSpec.parse("nan_loss@5:times=2")
    assert (s.point, s.step, s.times) == ("nan_loss", 5, 2)
    s = FaultSpec.parse("decode%0.25:times=3")
    assert (s.point, s.step, s.prob, s.times) == ("decode", None, 0.25, 3)
    s = FaultSpec.parse("straggler@3:delay=0.5")
    assert s.delay == 0.5
    with pytest.raises(ValueError):
        FaultSpec.parse("x@1:bogus=1")


def test_fault_plan_step_pinned_fires_once():
    plan = FaultPlan.parse("data_fetch@3")
    assert plan.check("data_fetch", 2) is None
    assert plan.check("data_fetch", 3) is not None
    assert plan.check("data_fetch", 3) is None       # times budget consumed
    assert plan.check("other_point", 3) is None


def test_fault_plan_probabilistic_is_deterministic():
    fires_a = [bool(FaultPlan.parse("decode%0.5:times=0").check("decode", i))
               for i in range(64)]
    fires_b = [bool(FaultPlan.parse("decode%0.5:times=0").check("decode", i))
               for i in range(64)]
    assert fires_a == fires_b                        # same seed -> same plan
    assert 8 < sum(fires_a) < 56                     # and it actually rolls
    fires_c = [bool(FaultPlan.parse("decode%0.5:times=0", seed=1)
                    .check("decode", i)) for i in range(64)]
    assert fires_a != fires_c                        # seed changes the draw


def test_fire_corrupt_delay_helpers():
    faults.install(FaultPlan.parse(
        "preempt@1,data_fetch@2,nan_loss@3,straggler@4:delay=0.75"))
    faults.fire("preempt", 0)                        # no-op off-schedule
    with pytest.raises(PreemptionFault):
        faults.fire("preempt", 1)
    with pytest.raises(TransientFault):
        faults.fire("data_fetch", 2)
    assert faults.corrupt("nan_loss", 2, 1.5) == 1.5
    assert np.isnan(faults.corrupt("nan_loss", 3, 1.5))
    assert faults.delay("straggler", 4) == 0.75
    assert faults.delay("straggler", 5) == 0.0


# -------------------------------------------------------------------------
# guards


def test_check_finite():
    assert check_finite(1.25) == 1.25
    with pytest.raises(NonFiniteLossError):
        check_finite(float("nan"), step=7)
    with pytest.raises(NonFiniteLossError):
        check_finite(float("inf"), name="grad_norm")


def test_divergence_detector_trips_on_sustained_spike():
    det = DivergenceDetector(ratio=3.0, patience=3, warmup=4)
    for i in range(10):
        det.update(1.0)
    det.update(10.0)
    det.update(10.0)
    with pytest.raises(DivergenceError):
        det.update(10.0)
    det.reset()
    det.update(10.0)                                 # fresh baseline, fine


def test_divergence_detector_tolerates_single_spike():
    det = DivergenceDetector(ratio=3.0, patience=3, warmup=4)
    for i in range(10):
        det.update(1.0)
    det.update(10.0)                                 # one bad step
    for i in range(10):
        det.update(1.0)                              # streak resets
    det.update(10.0)
    det.update(1.0)


def test_watchdog_per_sl_baseline_and_fallback():
    wd = StepTimeWatchdog(factor=3.0)
    assert wd.observe(64, 0.1).baseline is None      # cold start
    v = wd.observe(64, 0.1)
    assert v.baseline == pytest.approx(0.1) and not v.is_straggler
    assert wd.observe(64, 0.5).is_straggler          # 5x the SL-64 median
    # unseen SL falls back to the all-SL median
    v = wd.observe(128, 0.2)
    assert v.baseline is not None and not v.is_straggler


# -------------------------------------------------------------------------
# recovery primitives


def test_retry_with_backoff_succeeds_then_gives_up():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientFault("x", calls["n"])
        return "ok"

    assert retry_with_backoff(flaky, retries=3, base_delay=0.0) == "ok"
    assert calls["n"] == 3

    with pytest.raises(TransientFault):
        retry_with_backoff(lambda: (_ for _ in ()).throw(
            TransientFault("y", 0)), retries=2, base_delay=0.0)

    # preemption is not retryable
    def preempts():
        raise PreemptionFault("preempt", 0)

    with pytest.raises(PreemptionFault):
        retry_with_backoff(preempts, retries=5, base_delay=0.0)


def test_batch_skip_list():
    sl = BatchSkipList(skip_after=2)
    key = (0, 7)
    assert not sl.record_failure(key)
    assert not sl.should_skip(key)
    assert sl.record_failure(key)                    # second strike: poison
    assert sl.should_skip(key) and not sl.should_skip((0, 8))


# -------------------------------------------------------------------------
# trainer chaos paths


def _tiny_run():
    cfg = smoke_config("starcoder2-3b").with_overrides(num_layers=2,
                                                       d_model=64, d_ff=128,
                                                       vocab_size=256)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=8,
                        step=StepKind.TRAIN)
    mesh = MeshConfig(shape=(1,), axes=("data",))
    run = RunConfig(model=cfg, shape=shape, mesh=mesh,
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
                    param_dtype="float32", compute_dtype="float32")
    return cfg, run


class FakeClock:
    """Deterministic timer: one tick per call, so every measured step takes
    exactly 1.0 'seconds' and runtimes are bit-identical across runs."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def _make_trainer(tmp_path, *, ckpt_every=4, total=16, timer=None,
                  policy=None):
    cfg, run = _tiny_run()
    model = build_model(cfg, Runtime.from_run(run))
    data = DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                        vocab_size=cfg.vocab_size, granularity=8, seed=1)
    kw = {"timer": timer} if timer is not None else {}
    return Trainer(model, run, data, ckpt_dir=str(tmp_path),
                   ckpt_every=ckpt_every, total_steps=total,
                   policy=policy or RecoveryPolicy(backoff_base_s=0.0),
                   **kw)


def test_nan_loss_triggers_rollback_and_training_converges(tmp_path):
    faults.install(FaultPlan.parse("nan_loss@5"))
    tr = _make_trainer(tmp_path / "ck")
    rep = tr.train(12)
    assert rep.rollbacks == 1 and rep.guard_violations == 1
    assert rep.steps == 12 and len(rep.losses) == 12
    assert all(np.isfinite(rep.losses))              # poisoned step replayed
    assert np.mean(rep.losses[:4]) > np.mean(rep.losses[-4:])
    assert tr.epoch_log.num_iterations == 12


def test_persistent_nan_skips_poison_batch(tmp_path):
    # the same step NaNs twice: second rollback declares the batch poison
    # and training routes around it
    faults.install(FaultPlan.parse("nan_loss@5:times=2"))
    tr = _make_trainer(tmp_path / "ck")
    rep = tr.train(10)
    assert rep.rollbacks == 2
    assert rep.skipped_batches == 1
    assert rep.steps == 10 and len(rep.losses) == 10
    assert all(np.isfinite(rep.losses))


def test_guard_violation_without_ckpt_raises():
    cfg, run = _tiny_run()
    model = build_model(cfg, Runtime.from_run(run))
    data = DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                        vocab_size=cfg.vocab_size, granularity=8, seed=1)
    faults.install(FaultPlan.parse("nan_loss@2"))
    tr = Trainer(model, run, data)                   # no ckpt_dir: no net
    with pytest.raises(NonFiniteLossError):
        tr.train(5)


def test_data_fetch_fault_is_retried_transparently(tmp_path):
    faults.install(FaultPlan.parse("data_fetch@3"))
    tr = _make_trainer(tmp_path / "ck")
    rep = tr.train(8)
    assert rep.steps == 8 and len(rep.losses) == 8
    assert rep.rollbacks == 0                        # retry, not rollback


def test_preemption_resume_matches_fault_free_run_bitwise(tmp_path):
    steps = 12
    # fault-free reference with the deterministic clock
    ref = _make_trainer(tmp_path / "ref", timer=FakeClock())
    ref_rep = ref.train(steps)
    ref_sp = ref.seqpoints(error_threshold=0.1, n_threshold=32)

    # chaos run: transient loader fault, one NaN rollback, preemption at 9
    # with the emergency checkpoint silently corrupted, forcing restore to
    # fall back one step — the full acceptance gauntlet
    faults.install(FaultPlan.parse(
        "data_fetch@2,nan_loss@5,preempt@9,ckpt_corrupt@9"))
    ck = tmp_path / "ck"
    tr = _make_trainer(ck, timer=FakeClock())
    rep = tr.train(steps)
    assert rep.preempted and rep.steps == 9
    losses = list(rep.losses)
    pos = rep.steps
    resume_points = []
    for _ in range(4):                               # resume until complete
        if not rep.preempted and pos >= steps:
            break
        tr = _make_trainer(ck, timer=FakeClock())
        rep = tr.train(steps - pos)
        start = rep.resumed_from or 0
        resume_points.append(start)
        losses = losses[:start] + list(rep.losses)
        pos = start + rep.steps
    assert pos == steps

    # the corrupted emergency checkpoint (step 9) forced the first resume to
    # fall back to the step-8 periodic checkpoint
    assert resume_points[0] == 8
    np.testing.assert_allclose(losses, ref_rep.losses, rtol=1e-5, atol=1e-6)
    # EpochLog parity is bit-for-bit: same SLs, same (fake-clock) runtimes,
    # same wire-byte stats
    assert tr.epoch_log.to_jsonable() == ref.epoch_log.to_jsonable()
    sp = tr.seqpoints(error_threshold=0.1, n_threshold=32)
    assert sp.seq_lens == ref_sp.seq_lens
    np.testing.assert_array_equal(sp.weights, ref_sp.weights)
    assert (sp.k, sp.predicted, sp.actual) == \
        (ref_sp.k, ref_sp.predicted, ref_sp.actual)


def test_straggler_injection_is_flagged(tmp_path):
    faults.install(FaultPlan.parse("straggler@5:delay=1000"))
    tr = _make_trainer(tmp_path / "ck", timer=FakeClock())
    rep = tr.train(8)
    # fake clock: every step is 1.0s, the injected one 1001.0s
    assert rep.stragglers == 1
    assert rep.step_times[5] == pytest.approx(1001.0)


def test_divergence_guard_rolls_back_in_trainer(tmp_path):
    tr = _make_trainer(tmp_path / "ck")
    # hair-trigger detector fed a scripted loss spike at step 6
    tr.divergence = DivergenceDetector(ratio=1.5, patience=2, warmup=2)
    real_update = tr.divergence.update
    spiked = {"done": False}

    def scripted_update(loss, step=None):
        if step == 6 and not spiked["done"]:
            spiked["done"] = True
            real_update(loss * 100.0, step=step)
            real_update(loss * 100.0, step=step)
            return
        real_update(loss, step=step)

    tr.divergence.update = scripted_update
    rep = tr.train(10)
    assert rep.rollbacks >= 1
    assert rep.steps == 10


# -------------------------------------------------------------------------
# serve chaos paths


def _engine(**kw):
    cfg, run = _tiny_run()
    model = build_model(cfg, Runtime.from_run(run))
    params = model.init(jax.random.PRNGKey(0))
    from repro.serve.engine import ServeEngine
    return ServeEngine(model, params, batch_size=2, max_len=64,
                       sl_granularity=16, **kw)


def test_serve_tokens_out_counts_emitted_real_tokens():
    from repro.serve.engine import Request

    eng = _engine()
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=5)]
    eng.run_batch(reqs)
    rec = eng.log.iterations[-1]
    # one real request, five tokens emitted — the padded dummy slot and the
    # requested-vs-emitted distinction must not inflate the count
    assert rec.stats["tokens_out"] == 5.0
    assert rec.stats["tokens_out"] == float(len(reqs[0].output))


def test_serve_sheds_overload_instead_of_crashing():
    from repro.serve.engine import Request

    eng = _engine()
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=2) for _ in range(4)]
    out = eng.run_batch(reqs)
    assert out is reqs
    assert [r.shed for r in reqs] == [False, False, True, True]
    assert all(len(r.output) == 2 for r in reqs[:2])
    assert all(len(r.output) == 0 for r in reqs[2:])


def test_serve_deadline_curtails_decode():
    from repro.serve.engine import Request

    eng = _engine(deadline_s=0.0)                    # budget gone at once
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=8)]
    eng.run_batch(reqs)
    # prefill's token is delivered; the deadline stops all decode calls
    assert len(reqs[0].output) == 1
    rec = eng.log.iterations[-1]
    assert rec.stats["decode_steps"] == 0.0
    assert rec.stats["tokens_out"] == 1.0


def test_serve_decode_fault_is_retried():
    from repro.serve.engine import Request

    faults.install(FaultPlan.parse("decode@1"))
    eng = _engine(policy=RecoveryPolicy(backoff_base_s=0.0))
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=4)]
    eng.run_batch(reqs)
    assert len(reqs[0].output) == 4                  # fault was invisible


# -------------------------------------------------------------------------
# env wiring


def test_env_spec_round_trip():
    plan = FaultPlan.parse(os.environ.get("X_UNSET", "") or
                           "nan_loss@5,preempt@9", seed=3)
    assert [s.point for s in plan.specs] == ["nan_loss", "preempt"]
    assert plan.seed == 3
