"""repro.resilience: fault plans, guards, retries, and trainer chaos paths
(rollback on NaN, preemption + resume parity, corrupt-checkpoint fallback,
serve deadlines/shedding)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    MeshConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    StepKind,
    smoke_config,
)
from repro.data.batching import DataIterator
from repro.data.synthetic import IWSLT_LIKE
from repro.models import Runtime, build_model
from repro.resilience import (
    BatchSkipList,
    ClusterFailure,
    ClusterMonitor,
    DivergenceDetector,
    DivergenceError,
    FailureDomains,
    FaultPlan,
    FaultSpec,
    NonFiniteLossError,
    PeerHealthTracker,
    PeerLossFault,
    PreemptionFault,
    RecoveryPolicy,
    ReplicaSet,
    StepTimeWatchdog,
    TransientFault,
    backoff_delay,
    check_finite,
    faults,
    retry_with_backoff,
)
from repro.train.trainer import Trainer


@pytest.fixture(autouse=True)
def _no_global_faults():
    """Each test owns the global plan; none leaks to the next test."""
    prev = faults.install(None)
    yield
    faults.install(prev)


# -------------------------------------------------------------------------
# fault plans


def test_fault_spec_parsing():
    s = FaultSpec.parse("nan_loss@5:times=2")
    assert (s.point, s.step, s.times) == ("nan_loss", 5, 2)
    s = FaultSpec.parse("decode%0.25:times=3")
    assert (s.point, s.step, s.prob, s.times) == ("decode", None, 0.25, 3)
    s = FaultSpec.parse("straggler@3:delay=0.5")
    assert s.delay == 0.5
    s = FaultSpec.parse("peer_loss@7:host=2")
    assert (s.point, s.step, s.host) == ("peer_loss", 7, 2)
    s = FaultSpec.parse("peer_slow@4:host=1:delay=0.1")
    assert (s.host, s.delay) == (1, 0.1)
    with pytest.raises(ValueError):
        FaultSpec.parse("x@1:bogus=1")


def test_fault_plan_step_pinned_fires_once():
    plan = FaultPlan.parse("data_fetch@3")
    assert plan.check("data_fetch", 2) is None
    assert plan.check("data_fetch", 3) is not None
    assert plan.check("data_fetch", 3) is None       # times budget consumed
    assert plan.check("other_point", 3) is None


def test_fault_plan_probabilistic_is_deterministic():
    fires_a = [bool(FaultPlan.parse("decode%0.5:times=0").check("decode", i))
               for i in range(64)]
    fires_b = [bool(FaultPlan.parse("decode%0.5:times=0").check("decode", i))
               for i in range(64)]
    assert fires_a == fires_b                        # same seed -> same plan
    assert 8 < sum(fires_a) < 56                     # and it actually rolls
    fires_c = [bool(FaultPlan.parse("decode%0.5:times=0", seed=1)
                    .check("decode", i)) for i in range(64)]
    assert fires_a != fires_c                        # seed changes the draw


def test_fire_corrupt_delay_helpers():
    faults.install(FaultPlan.parse(
        "preempt@1,data_fetch@2,nan_loss@3,straggler@4:delay=0.75"))
    faults.fire("preempt", 0)                        # no-op off-schedule
    with pytest.raises(PreemptionFault):
        faults.fire("preempt", 1)
    with pytest.raises(TransientFault):
        faults.fire("data_fetch", 2)
    assert faults.corrupt("nan_loss", 2, 1.5) == 1.5
    assert np.isnan(faults.corrupt("nan_loss", 3, 1.5))
    assert faults.delay("straggler", 4) == 0.75
    assert faults.delay("straggler", 5) == 0.0


# -------------------------------------------------------------------------
# guards


def test_check_finite():
    assert check_finite(1.25) == 1.25
    with pytest.raises(NonFiniteLossError):
        check_finite(float("nan"), step=7)
    with pytest.raises(NonFiniteLossError):
        check_finite(float("inf"), name="grad_norm")


def test_divergence_detector_trips_on_sustained_spike():
    det = DivergenceDetector(ratio=3.0, patience=3, warmup=4)
    for i in range(10):
        det.update(1.0)
    det.update(10.0)
    det.update(10.0)
    with pytest.raises(DivergenceError):
        det.update(10.0)
    det.reset()
    det.update(10.0)                                 # fresh baseline, fine


def test_divergence_detector_tolerates_single_spike():
    det = DivergenceDetector(ratio=3.0, patience=3, warmup=4)
    for i in range(10):
        det.update(1.0)
    det.update(10.0)                                 # one bad step
    for i in range(10):
        det.update(1.0)                              # streak resets
    det.update(10.0)
    det.update(1.0)


def test_watchdog_per_sl_baseline_and_fallback():
    wd = StepTimeWatchdog(factor=3.0)
    assert wd.observe(64, 0.1).baseline is None      # cold start
    v = wd.observe(64, 0.1)
    assert v.baseline == pytest.approx(0.1) and not v.is_straggler
    assert wd.observe(64, 0.5).is_straggler          # 5x the SL-64 median
    # unseen SL falls back to the all-SL median
    v = wd.observe(128, 0.2)
    assert v.baseline is not None and not v.is_straggler


# -------------------------------------------------------------------------
# recovery primitives


def test_retry_with_backoff_succeeds_then_gives_up():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise TransientFault("x", calls["n"])
        return "ok"

    assert retry_with_backoff(flaky, retries=3, base_delay=0.0) == "ok"
    assert calls["n"] == 3

    with pytest.raises(TransientFault):
        retry_with_backoff(lambda: (_ for _ in ()).throw(
            TransientFault("y", 0)), retries=2, base_delay=0.0)

    # preemption is not retryable
    def preempts():
        raise PreemptionFault("preempt", 0)

    with pytest.raises(PreemptionFault):
        retry_with_backoff(preempts, retries=5, base_delay=0.0)


def test_batch_skip_list():
    sl = BatchSkipList(skip_after=2)
    key = (0, 7)
    assert not sl.record_failure(key)
    assert not sl.should_skip(key)
    assert sl.record_failure(key)                    # second strike: poison
    assert sl.should_skip(key) and not sl.should_skip((0, 8))


def test_batch_skip_list_state_round_trip():
    sl = BatchSkipList(skip_after=2)
    sl.record_failure((0, 7))
    sl.record_failure((0, 7))
    sl.record_failure((1, 3))
    snap = sl.state()
    import json
    json.dumps(snap)                                 # must be JSON-able
    other = BatchSkipList(skip_after=2)
    other.restore(snap)
    assert other.poisoned == {(0, 7)}
    assert other.record_failure((1, 3))              # count carried over
    # merging an older snapshot never undoes in-memory poison status
    other.restore({"failures": [[[0, 7], 1]], "skip": []})
    assert other.poisoned == {(0, 7), (1, 3)}
    other.restore(None)                              # no-op
    assert other.poisoned == {(0, 7), (1, 3)}


def test_backoff_delay_cap_and_deterministic_jitter():
    # uncapped exponential would hit 0.02 * 2**9 = 10.24s; the cap holds
    d = backoff_delay(10, base_delay=0.02, factor=2.0, max_delay_s=2.0,
                      jitter_frac=0.0)
    assert d == 2.0
    # jitter stays within +/- frac and never exceeds the cap
    for attempt in range(1, 12):
        d = backoff_delay(attempt, base_delay=0.02, factor=2.0,
                          max_delay_s=2.0, jitter_frac=0.25, jitter_seed=0,
                          label="x")
        raw = min(0.02 * 2.0 ** (attempt - 1), 2.0)
        assert 0.75 * raw <= d <= min(1.25 * raw, 2.0)
    # deterministic per seed (chaos replay parity) ...
    a = backoff_delay(3, jitter_seed=7, label="ckpt_save")
    b = backoff_delay(3, jitter_seed=7, label="ckpt_save")
    assert a == b
    # ... but replicas with different seeds desynchronize
    spread = {backoff_delay(3, jitter_seed=s, label="ckpt_save")
              for s in range(16)}
    assert len(spread) > 8


# -------------------------------------------------------------------------
# trainer chaos paths


def _tiny_run(mesh_shape=(1,), mesh_axes=("data",)):
    cfg = smoke_config("starcoder2-3b").with_overrides(num_layers=2,
                                                       d_model=64, d_ff=128,
                                                       vocab_size=256)
    shape = ShapeConfig("tiny", seq_len=32, global_batch=8,
                        step=StepKind.TRAIN)
    mesh = MeshConfig(shape=mesh_shape, axes=mesh_axes)
    run = RunConfig(model=cfg, shape=shape, mesh=mesh,
                    optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
                    param_dtype="float32", compute_dtype="float32")
    return cfg, run


class FakeClock:
    """Deterministic timer: one tick per call, so every measured step takes
    exactly 1.0 'seconds' and runtimes are bit-identical across runs."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


def _make_trainer(tmp_path, *, ckpt_every=4, total=16, timer=None,
                  policy=None, mesh_shape=(1,)):
    cfg, run = _tiny_run(mesh_shape=mesh_shape)
    model = build_model(cfg, Runtime.from_run(run))
    data = DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                        vocab_size=cfg.vocab_size, granularity=8, seed=1)
    kw = {"timer": timer} if timer is not None else {}
    return Trainer(model, run, data, ckpt_dir=str(tmp_path),
                   ckpt_every=ckpt_every, total_steps=total,
                   policy=policy or RecoveryPolicy(backoff_base_s=0.0),
                   **kw)


def test_nan_loss_triggers_rollback_and_training_converges(tmp_path):
    faults.install(FaultPlan.parse("nan_loss@5"))
    tr = _make_trainer(tmp_path / "ck")
    rep = tr.train(12)
    assert rep.rollbacks == 1 and rep.guard_violations == 1
    assert rep.steps == 12 and len(rep.losses) == 12
    assert all(np.isfinite(rep.losses))              # poisoned step replayed
    assert np.mean(rep.losses[:4]) > np.mean(rep.losses[-4:])
    assert tr.epoch_log.num_iterations == 12


def test_persistent_nan_skips_poison_batch(tmp_path):
    # the same step NaNs twice: second rollback declares the batch poison
    # and training routes around it
    faults.install(FaultPlan.parse("nan_loss@5:times=2"))
    tr = _make_trainer(tmp_path / "ck")
    rep = tr.train(10)
    assert rep.rollbacks == 2
    assert rep.skipped_batches == 1
    assert rep.steps == 10 and len(rep.losses) == 10
    assert all(np.isfinite(rep.losses))


def test_guard_violation_without_ckpt_raises():
    cfg, run = _tiny_run()
    model = build_model(cfg, Runtime.from_run(run))
    data = DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                        vocab_size=cfg.vocab_size, granularity=8, seed=1)
    faults.install(FaultPlan.parse("nan_loss@2"))
    tr = Trainer(model, run, data)                   # no ckpt_dir: no net
    with pytest.raises(NonFiniteLossError):
        tr.train(5)


def test_data_fetch_fault_is_retried_transparently(tmp_path):
    faults.install(FaultPlan.parse("data_fetch@3"))
    tr = _make_trainer(tmp_path / "ck")
    rep = tr.train(8)
    assert rep.steps == 8 and len(rep.losses) == 8
    assert rep.rollbacks == 0                        # retry, not rollback


def test_preemption_resume_matches_fault_free_run_bitwise(tmp_path):
    steps = 12
    # fault-free reference with the deterministic clock
    ref = _make_trainer(tmp_path / "ref", timer=FakeClock())
    ref_rep = ref.train(steps)
    ref_sp = ref.seqpoints(error_threshold=0.1, n_threshold=32)

    # chaos run: transient loader fault, one NaN rollback, preemption at 9
    # with the emergency checkpoint silently corrupted, forcing restore to
    # fall back one step — the full acceptance gauntlet
    faults.install(FaultPlan.parse(
        "data_fetch@2,nan_loss@5,preempt@9,ckpt_corrupt@9"))
    ck = tmp_path / "ck"
    tr = _make_trainer(ck, timer=FakeClock())
    rep = tr.train(steps)
    assert rep.preempted and rep.steps == 9
    losses = list(rep.losses)
    pos = rep.steps
    resume_points = []
    for _ in range(4):                               # resume until complete
        if not rep.preempted and pos >= steps:
            break
        tr = _make_trainer(ck, timer=FakeClock())
        rep = tr.train(steps - pos)
        start = rep.resumed_from or 0
        resume_points.append(start)
        losses = losses[:start] + list(rep.losses)
        pos = start + rep.steps
    assert pos == steps

    # the corrupted emergency checkpoint (step 9) forced the first resume to
    # fall back to the step-8 periodic checkpoint
    assert resume_points[0] == 8
    np.testing.assert_allclose(losses, ref_rep.losses, rtol=1e-5, atol=1e-6)
    # EpochLog parity is bit-for-bit: same SLs, same (fake-clock) runtimes,
    # same wire-byte stats
    assert tr.epoch_log.to_jsonable() == ref.epoch_log.to_jsonable()
    sp = tr.seqpoints(error_threshold=0.1, n_threshold=32)
    assert sp.seq_lens == ref_sp.seq_lens
    np.testing.assert_array_equal(sp.weights, ref_sp.weights)
    assert (sp.k, sp.predicted, sp.actual) == \
        (ref_sp.k, ref_sp.predicted, ref_sp.actual)


def test_straggler_injection_is_flagged(tmp_path):
    faults.install(FaultPlan.parse("straggler@5:delay=1000"))
    tr = _make_trainer(tmp_path / "ck", timer=FakeClock())
    rep = tr.train(8)
    # fake clock: every step is 1.0s, the injected one 1001.0s
    assert rep.stragglers == 1
    assert rep.step_times[5] == pytest.approx(1001.0)


def test_divergence_guard_rolls_back_in_trainer(tmp_path):
    tr = _make_trainer(tmp_path / "ck")
    # hair-trigger detector fed a scripted loss spike at step 6
    tr.divergence = DivergenceDetector(ratio=1.5, patience=2, warmup=2)
    real_update = tr.divergence.update
    spiked = {"done": False}

    def scripted_update(loss, step=None):
        if step == 6 and not spiked["done"]:
            spiked["done"] = True
            real_update(loss * 100.0, step=step)
            real_update(loss * 100.0, step=step)
            return
        real_update(loss, step=step)

    tr.divergence.update = scripted_update
    rep = tr.train(10)
    assert rep.rollbacks >= 1
    assert rep.steps == 10


# -------------------------------------------------------------------------
# serve chaos paths


def _engine(**kw):
    cfg, run = _tiny_run()
    model = build_model(cfg, Runtime.from_run(run))
    params = model.init(jax.random.PRNGKey(0))
    from repro.serve.engine import ServeEngine
    return ServeEngine(model, params, batch_size=2, max_len=64,
                       sl_granularity=16, **kw)


def test_serve_tokens_out_counts_emitted_real_tokens():
    from repro.serve.engine import Request

    eng = _engine()
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=5)]
    eng.run_batch(reqs)
    rec = eng.log.iterations[-1]
    # one real request, five tokens emitted — the padded dummy slot and the
    # requested-vs-emitted distinction must not inflate the count
    assert rec.stats["tokens_out"] == 5.0
    assert rec.stats["tokens_out"] == float(len(reqs[0].output))


def test_serve_sheds_overload_instead_of_crashing():
    from repro.serve.engine import Request

    eng = _engine()
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=2) for _ in range(4)]
    out = eng.run_batch(reqs)
    assert out is reqs
    assert [r.shed for r in reqs] == [False, False, True, True]
    assert all(len(r.output) == 2 for r in reqs[:2])
    assert all(len(r.output) == 0 for r in reqs[2:])


def test_serve_deadline_curtails_decode():
    from repro.serve.engine import Request

    eng = _engine(deadline_s=0.0)                    # budget gone at once
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=8)]
    eng.run_batch(reqs)
    # prefill's token is delivered; the deadline stops all decode calls
    assert len(reqs[0].output) == 1
    rec = eng.log.iterations[-1]
    assert rec.stats["decode_steps"] == 0.0
    assert rec.stats["tokens_out"] == 1.0


def test_serve_decode_fault_is_retried():
    from repro.serve.engine import Request

    faults.install(FaultPlan.parse("decode@1"))
    eng = _engine(policy=RecoveryPolicy(backoff_base_s=0.0))
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=4)]
    eng.run_batch(reqs)
    assert len(reqs[0].output) == 4                  # fault was invisible


# -------------------------------------------------------------------------
# multi-host failure domains (resilience.elastic)


def test_failure_domains_mapping_and_shrink():
    mesh = MeshConfig(shape=(4, 2), axes=("data", "model"))
    dom = FailureDomains.from_mesh(mesh)             # one host per data row
    assert dom.num_hosts == 4 and dom.devices_per_host == 2
    assert dom.devices_of(0) == [0, 1]
    assert dom.devices_of(3) == [6, 7]
    assert [dom.host_of(d) for d in range(8)] == [0, 0, 1, 1, 2, 2, 3, 3]
    assert dom.surviving_devices([1]) == [0, 1, 4, 5, 6, 7]
    new_mesh, new_dom = dom.surviving_mesh([1])
    assert new_mesh.shape == (3, 2) and new_mesh.axes == ("data", "model")
    assert new_dom.num_hosts == 3
    # losing nobody is the identity
    same_mesh, same_dom = dom.surviving_mesh([])
    assert same_mesh == mesh and same_dom is dom
    with pytest.raises(ClusterFailure):
        dom.surviving_mesh([0, 1, 2, 3])             # nothing left


def test_failure_domains_coarser_hosts():
    mesh = MeshConfig(shape=(4, 2), axes=("data", "model"))
    dom = FailureDomains.from_mesh(mesh, num_hosts=2)  # 2 data rows / host
    assert dom.devices_of(1) == [4, 5, 6, 7]
    new_mesh, _ = dom.surviving_mesh([0])
    assert new_mesh.shape == (2, 2)
    with pytest.raises(ValueError):                  # 4 rows, 3 hosts
        FailureDomains.from_mesh(mesh, num_hosts=3)


def test_peer_health_tracker_confirms_after_misses():
    tk = PeerHealthTracker([0, 1, 2], confirm_misses=2)
    v = tk.observe({0, 2}, tick=0)                   # host 1 misses once
    assert v.suspect == {1} and not v.confirmed_lost
    v = tk.observe({0, 1, 2}, tick=1)                # late beat resets it
    assert not v.suspect and not v.confirmed_lost
    v = tk.observe({0, 2}, tick=2)
    v = tk.observe({0, 2}, tick=3)                   # second consecutive miss
    assert v.confirmed_lost == {1}
    tk.forget([1])
    assert tk.hosts == (0, 2)


def test_cluster_monitor_confirms_peer_loss():
    faults.install(FaultPlan.parse("peer_loss@3:host=1"))
    mon = ClusterMonitor.from_mesh(MeshConfig(shape=(4,), axes=("data",)))
    for t in range(3):
        mon.pulse(t)                                 # all healthy
    mon.pulse(3)                                     # first missed beat
    assert mon.healthy_hosts == (0, 2, 3)
    with pytest.raises(PeerLossFault) as ei:
        mon.pulse(4)                                 # second miss: confirmed
    assert ei.value.hosts == {1}
    survivor = mon.after_loss(ei.value.hosts)
    assert survivor.domains.mesh.shape == (3,)
    assert survivor.hosts == (0, 1, 2)               # renumbered


def test_cluster_monitor_peer_slow_is_not_a_loss():
    faults.install(FaultPlan.parse("peer_slow@3:host=1:delay=0.1"))
    mon = ClusterMonitor.from_mesh(MeshConfig(shape=(4,), axes=("data",)))
    for t in range(8):
        mon.pulse(t)                                 # one miss never confirms
    assert mon.healthy_hosts == (0, 1, 2, 3)


def test_cluster_monitor_partition_loses_far_side():
    faults.install(FaultPlan.parse("mesh_partition@2:host=2"))
    mon = ClusterMonitor.from_mesh(MeshConfig(shape=(4,), axes=("data",)))
    mon.pulse(0)
    mon.pulse(1)
    mon.pulse(2)                                     # hosts 2,3 cut off
    with pytest.raises(PeerLossFault) as ei:
        mon.pulse(3)
    assert ei.value.hosts == {2, 3}


def test_replica_set_strikes_and_picks():
    rs = ReplicaSet(3)
    assert rs.pick_primary() == 0
    rs.mark_slow(0)
    assert rs.pick_primary() == 1
    assert rs.pick_hedge(exclude=1) == 2
    rs.mark_ok(0)
    assert rs.strikes(0) == 0
    assert ReplicaSet(1).pick_hedge(exclude=0) is None
    with pytest.raises(ValueError):
        ReplicaSet(0)


# -------------------------------------------------------------------------
# trainer tier-4: elastic re-mesh


def test_elastic_remesh_preserves_seqpoint_selection(tmp_path):
    steps = 12
    ref = _make_trainer(tmp_path / "ref", timer=FakeClock(),
                        mesh_shape=(4,))
    ref_rep = ref.train(steps)
    ref_sp = ref.seqpoints(error_threshold=0.1, n_threshold=32)

    # host 2 dies at step 6; confirmed one pulse later; the trainer
    # checkpoints, shrinks the mesh to 3 hosts, and finishes in-process
    faults.install(FaultPlan.parse("peer_loss@6:host=2"))
    tr = _make_trainer(tmp_path / "ck", timer=FakeClock(), mesh_shape=(4,))
    rep = tr.train(steps)
    assert rep.remeshes == 1 and rep.lost_hosts == [2]
    assert not rep.preempted and rep.steps == steps
    assert tr.run.mesh.shape == (3,)                 # DP axis shrunk
    assert tr.cluster.hosts == (0, 1, 2)             # survivors renumbered
    np.testing.assert_allclose(rep.losses, ref_rep.losses,
                               rtol=1e-5, atol=1e-6)
    # per-iteration (SL, runtime) parity is exact — SeqPoint selection only
    # reads those — while dp_wire_bytes legitimately changes with DP degree
    assert [it.seq_len for it in tr.epoch_log.iterations] == \
        [it.seq_len for it in ref.epoch_log.iterations]
    assert [it.runtime for it in tr.epoch_log.iterations] == \
        [it.runtime for it in ref.epoch_log.iterations]
    sp = tr.seqpoints(error_threshold=0.1, n_threshold=32)
    assert sp.seq_lens == ref_sp.seq_lens
    np.testing.assert_array_equal(sp.weights, ref_sp.weights)


def test_elastic_remesh_without_ckpt_raises():
    cfg, run = _tiny_run(mesh_shape=(4,))
    model = build_model(cfg, Runtime.from_run(run))
    data = DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                        vocab_size=cfg.vocab_size, granularity=8, seed=1)
    faults.install(FaultPlan.parse("peer_loss@2:host=1"))
    tr = Trainer(model, run, data)                   # no ckpt: no tier 4
    with pytest.raises(PeerLossFault):
        tr.train(6)


def test_single_host_loss_is_cluster_failure(tmp_path):
    # a (1,) mesh has one failure domain; losing it cannot be re-meshed
    faults.install(FaultPlan.parse("peer_loss@2:host=0"))
    tr = _make_trainer(tmp_path / "ck")
    with pytest.raises(ClusterFailure):
        tr.train(6)


# -------------------------------------------------------------------------
# skip list survives preemption resume


def test_skiplist_survives_preemption_resume(tmp_path):
    # batch at step 5 is persistently poisoned (two NaNs), then a preemption
    # at step 8 forces a process restart: the resumed trainer must remember
    # the poison without paying the discovery rollbacks again
    faults.install(FaultPlan.parse("nan_loss@5:times=2,preempt@8"))
    ck = tmp_path / "ck"
    tr = _make_trainer(ck)
    rep = tr.train(12)
    assert rep.rollbacks == 2 and rep.skipped_batches == 1
    assert rep.preempted and rep.steps == 8
    poisoned = tr.skiplist.poisoned
    assert poisoned

    tr2 = _make_trainer(ck)
    rep2 = tr2.train(12 - rep.steps)
    assert tr2.skiplist.poisoned == poisoned         # restored from extra
    assert rep2.rollbacks == 0                       # no rediscovery
    assert rep2.steps == 12 - rep.steps and not rep2.preempted


# -------------------------------------------------------------------------
# serve: deadline/shed interplay and request hedging


def test_serve_deadline_only_checked_between_decode_steps():
    from repro.serve.engine import Request

    # zero budget, but the single requested token comes from prefill: it is
    # delivered because the deadline is only consulted between decode steps
    from repro import obs

    eng = _engine(deadline_s=0.0)
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=1)]
    before = obs.metrics.counter("serve_deadline_exceeded_total").value
    eng.run_batch(reqs)
    after = obs.metrics.counter("serve_deadline_exceeded_total").value
    assert len(reqs[0].output) == 1
    assert eng.log.iterations[-1].stats["decode_steps"] == 0.0
    assert after == before                           # never even checked


def test_serve_shed_request_requeues_cleanly():
    from repro.serve.engine import Request

    eng = _engine()
    reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                    max_new_tokens=3) for _ in range(3)]
    eng.run_batch(reqs)
    assert reqs[2].shed and reqs[2].output == []     # empty: safe to requeue
    eng.run_batch([reqs[2]])
    assert not reqs[2].shed                          # admitted this time
    assert len(reqs[2].output) == 3


def _run_serve(n_replicas, plan, n_batches=10, max_new_tokens=8):
    from repro.serve.engine import Request

    faults.install(FaultPlan.parse(plan) if plan else None)
    eng = _engine(n_replicas=n_replicas, hedge_factor=3.0,
                  policy=RecoveryPolicy(backoff_base_s=0.0))
    lat = []
    all_reqs = []
    for _ in range(n_batches):
        reqs = [Request(prompt=np.arange(1, 9, dtype=np.int32),
                        max_new_tokens=max_new_tokens)]
        eng.run_batch(reqs)
        all_reqs.extend(reqs)
        lat.append(eng.log.iterations[-1].stats["latency_s"])
    return eng, lat, all_reqs


def test_hedged_serve_cuts_tail_latency():
    # the 9th execution runs on a degraded link: every decode call is 2.0s
    # late (virtually). Unhedged eats the full tail; hedged re-issues on the
    # healthy replica and commits the fast finisher.
    plan = "peer_slow@8:delay=2.0"
    _, unhedged, _ = _run_serve(1, plan)
    eng, hedged, reqs = _run_serve(2, plan)
    assert unhedged[8] > 10.0                        # 7 decode calls x 2.0s
    assert hedged[8] < unhedged[8] / 2
    assert np.percentile(hedged, 99) < np.percentile(unhedged, 99)
    rec = eng.log.iterations[8]
    assert rec.stats["hedged"] == 1.0
    assert rec.stats["replica"] == 1.0               # hedge replica won
    from repro import obs
    assert obs.metrics.counter("serve_hedges_total").value >= 1
    assert obs.metrics.counter("serve_hedge_wins_total").value >= 1
    assert eng.replicas.strikes(0) >= 1              # loser took a strike


def test_hedge_cancelled_tokens_never_reach_caller_or_counter():
    eng, _, reqs = _run_serve(2, "peer_slow@8:delay=2.0")
    # exactly max_new_tokens per request — a double-commit would show up as
    # 16 tokens on the hedged batch's request
    assert all(len(r.output) == 8 for r in reqs)
    assert all(it.stats["tokens_out"] == 8.0 for it in eng.log.iterations)
    assert sum(it.stats["tokens_out"] for it in eng.log.iterations) == 80.0


def test_unhedged_single_replica_never_hedges():
    eng, _, _ = _run_serve(1, "peer_slow@4:delay=2.0", n_batches=6)
    assert all(it.stats["hedged"] == 0.0 for it in eng.log.iterations)


# -------------------------------------------------------------------------
# env wiring


def test_env_spec_round_trip():
    plan = FaultPlan.parse(os.environ.get("X_UNSET", "") or
                           "nan_loss@5,preempt@9", seed=3)
    assert [s.point for s in plan.specs] == ["nan_loss", "preempt"]
    assert plan.seed == 3
