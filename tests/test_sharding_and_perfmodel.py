"""Sharding rules + perfmodel units (pure spec computation, no mesh)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import MULTI_POD, SINGLE_POD, get_model_config, \
    get_shape, smoke_config
from repro.dist.sharding import batch_specs, param_specs
from repro.models import Runtime, build_model
from repro.perfmodel.hlo import parse_collectives
from repro.perfmodel.machine import PAPER_CONFIGS, TPU_V5E
from repro.perfmodel.model_flops import model_flops, param_count


def _specs_for(arch, mesh=SINGLE_POD, fsdp=False, **kw):
    cfg = get_model_config(arch)
    # production param dtype (bf16) — the fsdp size threshold keys on it
    model = build_model(cfg, Runtime(tp_degree=mesh.model_degree,
                                     param_dtype=jnp.bfloat16))
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    return cfg, shapes, param_specs(shapes, cfg, mesh, fsdp=fsdp, **kw)


def test_dense_rules():
    cfg, shapes, specs = _specs_for("mistral-nemo-12b")
    assert specs["embed"] == P("model", None)
    assert specs["lm_head"] == P(None, "model")
    layer0 = specs["layers"][0]
    assert layer0["mixer"]["wq"] == P(None, None, "model")
    assert layer0["mixer"]["wo"] == P(None, "model", None)
    # kv heads (8) don't divide tp (16) -> replicated kv projections
    assert layer0["mixer"]["wk"] == P(None, None, None)
    assert layer0["ffn"]["wi"] == P(None, None, "model")


def test_moe_ep_vs_tp_rules():
    _, _, ds = _specs_for("deepseek-v3-671b")           # 256 % 16 == 0 -> EP
    assert ds["layers"][0]["ffn"]["e_wg"] == P(None, "model", None, None)
    _, _, qw = _specs_for("qwen2-moe-a2.7b")            # 60 % 16 != 0 -> TP
    assert qw["layers"][0]["ffn"]["e_wg"] == P(None, None, None, "model")
    assert qw["layers"][0]["ffn"]["e_wo"] == P(None, None, "model", None)


def test_fsdp_threshold_and_axes():
    cfg, shapes, specs = _specs_for("qwen2-72b", fsdp=True)
    # big FFN kernels get the data axis; the (model-sharded, small) embed
    # table does not
    wi = specs["layers"][0]["ffn"]["wi"]
    assert "data" in jax.tree_util.tree_leaves(tuple(wi)) or \
        any(ax == "data" for ax in wi if ax is not None)
    assert specs["embed"] == P("model", None)
    # cross-pod FSDP on the multi-pod mesh
    _, _, sp = _specs_for("deepseek-v3-671b", mesh=MULTI_POD, fsdp=True,
                          fsdp_over_pods=True)
    flat = jax.tree_util.tree_leaves(
        sp, is_leaf=lambda x: isinstance(x, P))
    assert any(("pod", "data") in tuple(s) for s in flat)


def test_batch_specs_shard_or_replicate():
    cfg = get_model_config("qwen2-72b")
    model = build_model(cfg, Runtime(tp_degree=16))
    train = get_shape("train_4k")
    bs = batch_specs(model.input_specs(train), SINGLE_POD, train)
    assert bs["tokens"] == P("data", None)
    long = get_shape("long_500k")
    bs2 = batch_specs(
        {"token": jax.ShapeDtypeStruct((1, 1), jnp.int32)}, SINGLE_POD, long)
    assert bs2["token"] == P(None, None)        # B=1 -> replicated


def test_param_count_sane():
    # published totals (+-15%): qwen2-72b ~72B, mistral-nemo ~12B
    assert abs(param_count(get_model_config("qwen2-72b")) - 72e9) < 12e9
    assert abs(param_count(get_model_config("mistral-nemo-12b")) - 12e9) \
        < 2.5e9
    ds = get_model_config("deepseek-v3-671b")
    assert abs(param_count(ds) - 671e9) < 80e9
    # active params ~37B for deepseek-v3
    assert abs(param_count(ds, active=True) - 37e9) < 8e9


def test_model_flops_scaling():
    cfg = get_model_config("starcoder2-3b")
    t = model_flops(cfg, get_shape("train_4k"))
    p = model_flops(cfg, get_shape("prefill_32k"))
    # train = 6ND vs prefill = 2ND with equal token counts
    assert np.isclose(t / p, 3.0, rtol=1e-6)


def test_hlo_collective_parser():
    txt = """
  %ag = bf16[128,256]{1,0} all-gather(bf16[8,256]{1,0} %x), dims={0}
  %ar.1 = f32[1024]{0} all-reduce-start(f32[1024]{0} %y), replica_groups={}
  %rs = f32[64]{0} reduce-scatter(f32[1024]{0} %z), dimensions={0}
  %cp = u8[32]{0} collective-permute(u8[32]{0} %w), channel_id=3
"""
    stats = parse_collectives(txt)
    assert stats.count["all-gather"] == 1
    assert stats.buffer_bytes["all-gather"] == 128 * 256 * 2
    assert stats.count["all-reduce"] == 1
    assert stats.buffer_bytes["all-reduce"] == 4096
    assert stats.count["reduce-scatter"] == 1
    assert stats.buffer_bytes["reduce-scatter"] == 4096   # operand counted
    assert stats.count["collective-permute"] == 1
    # wire factors: ar 2x, others 1x
    assert stats.wire_bytes == (128 * 256 * 2 + 2 * 4096 + 4096 + 32)


def test_machine_configs_ordering():
    f, b, c = 1e15, 1e12, 1e10
    t1 = PAPER_CONFIGS["config1"].step_time(f, b, c)
    t2 = PAPER_CONFIGS["config2"].step_time(f, b, c)
    t3 = PAPER_CONFIGS["config3"].step_time(f, b, c)
    assert t2 > t1 and t3 > t2          # slower clocks/core counts
    assert TPU_V5E.step_time_sum(f, b, c) >= TPU_V5E.step_time(f, b, c)
