"""Regenerate results/*.md tables from the jsonl records."""
import json
import os

HERE = os.path.dirname(__file__)


def roofline_table():
    out = ["| arch | shape | compute s | memory s | collective s | dominant"
           " | useful FLOPs ratio | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for line in open(os.path.join(HERE, "dryrun_roofline.jsonl")):
        r = json.loads(line)
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL:"
                       f" {r['error'][:60]} | | | | | |")
            continue
        t = r["terms"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['useful_flops_ratio']:.3f} | "
            f"{100*r['roofline_fraction']:.1f}% |")
    with open(os.path.join(HERE, "roofline_table.md"), "w") as f:
        f.write("\n".join(out) + "\n")


def perf_table():
    rows = []
    for name in sorted(os.listdir(HERE)):
        if not (name.startswith("perf_") and name.endswith(".json")):
            continue
        p = json.load(open(os.path.join(HERE, name)))
        b, o = p["baseline"], p["optimized"]
        rows.append(
            f"| {p['arch']}/{p['shape']} | {p['opt']} | "
            f"{b['bound_s']:.3f} ({b['dominant'].replace('_s','')}) | "
            f"{o['bound_s']:.3f} ({o['dominant'].replace('_s','')}) | "
            f"{p['speedup']:.2f}x | {100*b['fraction']:.1f}% -> "
            f"{100*o['fraction']:.1f}% | {p['confirmed']} |")
    out = ["| cell | opt | baseline bound | optimized bound | speedup |"
           " roofline frac | confirmed |",
           "|---|---|---|---|---|---|---|"] + rows
    with open(os.path.join(HERE, "perf_table.md"), "w") as f:
        f.write("\n".join(out) + "\n")


if __name__ == "__main__":
    roofline_table()
    perf_table()
    print(open(os.path.join(HERE, "perf_table.md")).read())
