"""End-to-end driver: train a ~110M-param LM for a few hundred steps on CPU
with variable-SL batches, checkpoints + auto-resume, and SeqPoint logging.

    PYTHONPATH=src python examples/train_lm.py --steps 300

Kill it mid-run and re-run: it resumes from the last checkpoint.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import (
    MeshConfig,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    StepKind,
)
from repro.data.batching import DataIterator
from repro.data.synthetic import lm_documents
from repro.models import Runtime, build_model
from repro.train.trainer import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="lm-110m", family="dense", num_layers=args.layers,
        d_model=args.d_model, d_ff=4 * args.d_model, vocab_size=32_000,
        num_heads=args.d_model // 64, num_kv_heads=args.d_model // 64 // 2)
    from repro.perfmodel.model_flops import param_count
    print(f"model: {param_count(cfg)/1e6:.0f}M params (non-embedding)")

    shape = ShapeConfig("train", seq_len=args.seq, global_batch=args.batch,
                        step=StepKind.TRAIN)
    run = RunConfig(model=cfg, shape=shape,
                    mesh=MeshConfig(shape=(1,), axes=("data",)),
                    optimizer=OptimizerConfig(lr=3e-4, warmup_steps=20),
                    param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg, Runtime.from_run(run))
    data = DataIterator(lm_documents(args.seq), samples_per_epoch=4096,
                        batch_size=args.batch, vocab_size=cfg.vocab_size,
                        granularity=32, seed=0)
    trainer = Trainer(model, run, data, ckpt_dir=args.ckpt_dir,
                      ckpt_every=50, total_steps=args.steps)
    report = trainer.train(args.steps)
    print(f"steps={report.steps} resumed_from={report.resumed_from} "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f} "
          f"median_step={1e3*np.median(report.step_times):.0f}ms "
          f"stragglers={report.stragglers}")
    sp = trainer.seqpoints(error_threshold=0.05)
    print(f"SeqPoints for this run: {sp.num_points} SLs {sp.seq_lens} "
          f"(error {100*sp.error:.2f}%)")


if __name__ == "__main__":
    main()
