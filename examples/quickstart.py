"""Quickstart: SeqPoint in two minutes.

Trains a tiny GNMT on synthetic IWSLT-like data for one short epoch while
the trainer logs (SL, runtime) per iteration, then selects SeqPoints and
shows how few iterations reproduce the epoch's total time — the paper's core
claim, end to end.

With observability on (``--obs-dir DIR`` or ``REPRO_OBS_DIR=DIR``), the run
also writes a Perfetto-loadable Chrome trace, a metrics snapshot with
SL-keyed step-time histograms, and a JSONL event log, and checks the
SeqPoint projection live against the measured epoch (repro.obs).

    PYTHONPATH=src python examples/quickstart.py [--obs-dir results/obs]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import obs
from repro.core import select_seqpoints, frequent, median, worst, prior
from repro.core.characterize import WallclockProvider, epoch_log_from_plan
from repro.core.reproduction import SETUPS
from repro.data.batching import plan_epoch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--obs-dir", default=os.environ.get("REPRO_OBS_DIR"),
                    help="enable tracing/metrics/events, export here")
    args = ap.parse_args()
    if args.obs_dir:
        obs.enable(out_dir=args.obs_dir)

    setup = SETUPS["gnmt"]()
    rng = np.random.RandomState(0)
    sls = setup["dist"].sample(rng, 1280)
    plan = plan_epoch(sls, setup["batch_size"],
                      granularity=setup["granularity"])
    print(f"epoch: {plan.num_batches} iterations, "
          f"{len(set(map(int, plan.padded_sls)))} unique padded SLs")
    obs.event("run_start", example="quickstart", network="gnmt",
              iterations=plan.num_batches)

    print("profiling every unique SL (the expensive ground-truth pass)...")
    provider = WallclockProvider(setup["step_builder"], repeats=3)
    with obs.span("quickstart/profile_epoch"):
        log = epoch_log_from_plan(plan, provider)
    print(f"measured epoch time: {log.total_runtime:.2f}s")

    with obs.span("quickstart/select_seqpoints"):
        sp = select_seqpoints(log, error_threshold=0.02)
    print(f"\nSeqPoints: {sp.num_points} iterations (k={sp.k}) "
          f"-> projected {sp.predicted:.2f}s, error {100*sp.error:.2f}%")
    print(f"  SLs: {sp.seq_lens}")
    obs.event("seqpoints_selected", num_points=sp.num_points, k=sp.k,
              error=sp.error, converged=sp.meta.get("converged"))
    for name, fn in (("frequent", frequent), ("median", median),
                     ("worst", worst), ("prior", prior)):
        b = fn(log)
        print(f"  {name:9s}: {b.num_points:3d} iterations, "
              f"error {100*b.error:6.2f}%")
    red = plan.num_batches / sp.num_points
    print(f"\nprofiling reduction: {red:.0f}x fewer iterations "
          f"(paper reports 214x/345x at full dataset scale)")

    # live projection-error check: price every logged iteration by its
    # nearest SeqPoint and compare against the measured epoch total
    monitor = obs.ProjectionMonitor(sp)
    monitor.observe_log(log)
    rep = monitor.report()
    print(f"projection monitor: projected {rep.projected_total:.2f}s vs "
          f"measured {rep.measured_total:.2f}s "
          f"(rel error {100*rep.rel_error:.2f}%, "
          f"{len(rep.per_sl)} SLs tracked)")
    obs.event("projection_report", projected=rep.projected_total,
              measured=rep.measured_total, rel_error=rep.rel_error)

    obs.event("run_end", example="quickstart")
    if args.obs_dir:
        paths = obs.export_all()
        print("\nobservability artifacts:")
        for kind, path in sorted(paths.items()):
            print(f"  {kind:13s} {path}")


if __name__ == "__main__":
    main()
