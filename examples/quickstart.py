"""Quickstart: SeqPoint in two minutes.

Trains a tiny GNMT on synthetic IWSLT-like data for one short epoch while
the trainer logs (SL, runtime) per iteration, then selects SeqPoints and
shows how few iterations reproduce the epoch's total time — the paper's core
claim, end to end.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import select_seqpoints, frequent, median, worst, prior
from repro.core.characterize import WallclockProvider, epoch_log_from_plan
from repro.core.reproduction import SETUPS
from repro.data.batching import plan_epoch


def main() -> None:
    setup = SETUPS["gnmt"]()
    rng = np.random.RandomState(0)
    sls = setup["dist"].sample(rng, 1280)
    plan = plan_epoch(sls, setup["batch_size"],
                      granularity=setup["granularity"])
    print(f"epoch: {plan.num_batches} iterations, "
          f"{len(set(map(int, plan.padded_sls)))} unique padded SLs")

    print("profiling every unique SL (the expensive ground-truth pass)...")
    provider = WallclockProvider(setup["step_builder"], repeats=3)
    log = epoch_log_from_plan(plan, provider)
    print(f"measured epoch time: {log.total_runtime:.2f}s")

    sp = select_seqpoints(log, error_threshold=0.02)
    print(f"\nSeqPoints: {sp.num_points} iterations (k={sp.k}) "
          f"-> projected {sp.predicted:.2f}s, error {100*sp.error:.2f}%")
    print(f"  SLs: {sp.seq_lens}")
    for name, fn in (("frequent", frequent), ("median", median),
                     ("worst", worst), ("prior", prior)):
        b = fn(log)
        print(f"  {name:9s}: {b.num_points:3d} iterations, "
              f"error {100*b.error:6.2f}%")
    red = plan.num_batches / sp.num_points
    print(f"\nprofiling reduction: {red:.0f}x fewer iterations "
          f"(paper reports 214x/345x at full dataset scale)")


if __name__ == "__main__":
    main()
