"""Quickstart: SeqPoint in two minutes.

Trains a tiny GNMT on synthetic IWSLT-like data for one short epoch while
the trainer logs (SL, runtime) per iteration, then selects SeqPoints and
shows how few iterations reproduce the epoch's total time — the paper's core
claim, end to end.

With observability on (``--obs-dir DIR`` or ``REPRO_OBS_DIR=DIR``), the run
also writes a Perfetto-loadable Chrome trace, a metrics snapshot with
SL-keyed step-time histograms, and a JSONL event log, and checks the
SeqPoint projection live against the measured epoch (repro.obs).

With fault injection armed (``REPRO_FAULTS=<plan>`` or ``--chaos``), the run
finishes with two chaos drills: a single-process one (NaN loss, preemption,
corrupt checkpoint, flaky loader) and a multi-host one (a peer lost mid-run
forces an elastic re-mesh onto the surviving hosts) — both must recover and
produce the same SeqPoint selection as a fault-free reference run
(repro.resilience).

With ``--serve-sched``, the run is a serving-load drill instead: a skewed
SL request stream through the SL-aware continuous-batching scheduler
(repro.serve.sched) vs the run-to-completion baseline, with a live
Prometheus scrape of the serve metrics mid-run; exits non-zero unless the
scheduler cuts padding waste by >= 25% at equal tokens served.

    PYTHONPATH=src python examples/quickstart.py [--obs-dir results/obs]
    REPRO_FAULTS="nan_loss@5,preempt@9,ckpt_corrupt@9" \
        PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --serve-sched
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import obs
from repro.core import select_seqpoints, frequent, median, worst, prior
from repro.core.characterize import WallclockProvider, epoch_log_from_plan
from repro.core.reproduction import SETUPS
from repro.data.batching import plan_epoch

# fires data-loader, NaN-loss, straggler, preemption, and silent-checkpoint
# -corruption faults inside a 14-step run checkpointed every 4 steps
DEFAULT_CHAOS_SPEC = ("data_fetch@2,nan_loss@5,straggler@6:delay=0.05,"
                      "preempt@9,ckpt_corrupt@9")

# multi-host drill: a late heartbeat at step 4, then host 1 of 4 dies at
# step 7 — the trainer must confirm the loss, shrink the mesh to 3 hosts,
# and finish with the fault-free SeqPoint selection
ELASTIC_CHAOS_SPEC = "peer_slow@4:host=2:delay=0.02,peer_loss@7:host=1"


def chaos_drill() -> bool:
    """Train under injected faults, recover, and check SeqPoint parity
    against a fault-free reference run. Returns True on parity."""
    from repro.configs import (
        MeshConfig,
        OptimizerConfig,
        RunConfig,
        ShapeConfig,
        StepKind,
        smoke_config,
    )
    from repro.data.batching import DataIterator
    from repro.data.synthetic import IWSLT_LIKE
    from repro.models import Runtime, build_model
    from repro.resilience import faults
    from repro.train.trainer import Trainer

    spec = os.environ.get("REPRO_FAULTS") or DEFAULT_CHAOS_SPEC
    seed = int(os.environ.get("REPRO_FAULTS_SEED", "0"))
    steps = 14

    def make_trainer(ckpt_dir):
        cfg = smoke_config("starcoder2-3b").with_overrides(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256)
        run = RunConfig(
            model=cfg,
            shape=ShapeConfig("chaos", seq_len=32, global_batch=8,
                              step=StepKind.TRAIN),
            mesh=MeshConfig(shape=(1,), axes=("data",)),
            optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
            param_dtype="float32", compute_dtype="float32")
        data = DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                            vocab_size=cfg.vocab_size, granularity=8, seed=1)
        model = build_model(cfg, Runtime.from_run(run))
        return Trainer(model, run, data, ckpt_dir=ckpt_dir, ckpt_every=4,
                       total_steps=steps + 2)

    obs.event("chaos_drill_start", spec=spec, seed=seed, steps=steps)
    print(f"\nchaos drill: {steps} steps under REPRO_FAULTS={spec!r}")
    faults.install(None)                      # fault-free reference first
    with tempfile.TemporaryDirectory() as d:
        ref_tr = make_trainer(os.path.join(d, "ck"))
        ref_rep = ref_tr.train(steps)
        ref_sp = ref_tr.seqpoints(error_threshold=0.1, n_threshold=32)

    faults.install(faults.FaultPlan.parse(spec, seed=seed))
    with tempfile.TemporaryDirectory() as d:
        ck = os.path.join(d, "ck")
        tr = make_trainer(ck)
        rep = tr.train(steps)
        losses = list(rep.losses)
        pos = (rep.resumed_from or 0) + rep.steps
        restarts = 0
        while rep.preempted or pos < steps:   # resume until the run is done
            restarts += 1
            tr = make_trainer(ck)
            rep = tr.train(steps - pos)
            start = rep.resumed_from or 0
            losses = losses[:start] + list(rep.losses)
            pos = start + rep.steps
        sp = tr.seqpoints(error_threshold=0.1, n_threshold=32)
    faults.install(None)

    parity = (sp.seq_lens == ref_sp.seq_lens
              and np.allclose(sp.weights, ref_sp.weights)
              and np.allclose(losses, ref_rep.losses, rtol=1e-5, atol=1e-6))
    print(f"  recovered: {restarts} restart(s), {rep.rollbacks} rollback(s) "
          f"in final segment, epoch log {tr.epoch_log.num_iterations} "
          f"iterations")
    print(f"  seqpoint parity vs fault-free run: "
          f"{'OK' if parity else 'MISMATCH'} "
          f"(SLs {sp.seq_lens} == {ref_sp.seq_lens})")
    obs.event("chaos_drill_end", ok=bool(parity), restarts=restarts,
              seqpoint_sls=sp.seq_lens)
    return parity


def elastic_drill() -> bool:
    """Lose a host mid-run on a 4-way DP mesh, re-mesh over the survivors,
    and check SeqPoint parity against a fault-free reference. Returns True
    on parity."""
    from repro.configs import (
        MeshConfig,
        OptimizerConfig,
        RunConfig,
        ShapeConfig,
        StepKind,
        smoke_config,
    )
    from repro.data.batching import DataIterator
    from repro.data.synthetic import IWSLT_LIKE
    from repro.models import Runtime, build_model
    from repro.resilience import faults
    from repro.train.trainer import Trainer

    spec = ELASTIC_CHAOS_SPEC
    seed = int(os.environ.get("REPRO_FAULTS_SEED", "0"))
    steps = 12

    def make_trainer(ckpt_dir):
        cfg = smoke_config("starcoder2-3b").with_overrides(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256)
        run = RunConfig(
            model=cfg,
            shape=ShapeConfig("elastic", seq_len=32, global_batch=8,
                              step=StepKind.TRAIN),
            mesh=MeshConfig(shape=(4,), axes=("data",)),
            optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
            param_dtype="float32", compute_dtype="float32")
        data = DataIterator(IWSLT_LIKE, samples_per_epoch=256, batch_size=8,
                            vocab_size=cfg.vocab_size, granularity=8, seed=1)
        model = build_model(cfg, Runtime.from_run(run))
        return Trainer(model, run, data, ckpt_dir=ckpt_dir, ckpt_every=4,
                       total_steps=steps + 2)

    obs.event("elastic_drill_start", spec=spec, seed=seed, steps=steps)
    print(f"\nelastic drill: {steps} steps on a 4-host DP mesh under "
          f"{spec!r}")
    faults.install(None)                      # fault-free reference first
    with tempfile.TemporaryDirectory() as d:
        ref_tr = make_trainer(os.path.join(d, "ck"))
        ref_rep = ref_tr.train(steps)
        ref_sp = ref_tr.seqpoints(error_threshold=0.1, n_threshold=32)

    faults.install(faults.FaultPlan.parse(spec, seed=seed))
    with tempfile.TemporaryDirectory() as d:
        tr = make_trainer(os.path.join(d, "ck"))
        rep = tr.train(steps)                 # re-mesh happens in-call
        sp = tr.seqpoints(error_threshold=0.1, n_threshold=32)
    faults.install(None)

    # parity is on losses and the SeqPoint selection — (SL, runtime) records
    # are what selection reads; dp_wire_bytes legitimately shrinks with DP
    parity = (rep.remeshes == 1
              and tr.run.mesh.shape == (3,)
              and sp.seq_lens == ref_sp.seq_lens
              and np.allclose(sp.weights, ref_sp.weights)
              and np.allclose(rep.losses, ref_rep.losses,
                              rtol=1e-5, atol=1e-6))
    print(f"  lost host(s) {rep.lost_hosts}: {rep.remeshes} re-mesh(es), "
          f"mesh {(4,)} -> {tr.run.mesh.shape}, epoch log "
          f"{tr.epoch_log.num_iterations} iterations")
    print(f"  seqpoint parity vs fault-free run: "
          f"{'OK' if parity else 'MISMATCH'} "
          f"(SLs {sp.seq_lens} == {ref_sp.seq_lens})")
    obs.event("elastic_drill_end", ok=bool(parity), remeshes=rep.remeshes,
              lost_hosts=list(rep.lost_hosts), seqpoint_sls=sp.seq_lens)
    return parity


def serve_drill() -> bool:
    """Skewed-SL serving-load smoke: the SL-aware continuous-batching
    scheduler (``repro.serve.sched``) vs the run-to-completion baseline on
    the same request stream, with a live Prometheus scrape mid-run.
    Returns True when the scheduler serves the same tokens with >= 25%
    lower padding waste and higher grid throughput."""
    import urllib.request

    import jax

    from repro.configs import (
        MeshConfig,
        OptimizerConfig,
        RunConfig,
        ShapeConfig,
        StepKind,
        smoke_config,
    )
    from repro.models import Runtime, build_model
    from repro.serve import Request, ServeEngine
    from repro.serve.sched import BucketAffinePolicy, run_to_completion

    def make_engine():
        cfg = smoke_config("starcoder2-3b").with_overrides(
            num_layers=2, d_model=64, d_ff=128, vocab_size=256)
        run = RunConfig(
            model=cfg,
            shape=ShapeConfig("serve", seq_len=32, global_batch=8,
                              step=StepKind.TRAIN),
            mesh=MeshConfig(shape=(1,), axes=("data",)),
            optimizer=OptimizerConfig(lr=1e-3, warmup_steps=2),
            param_dtype="float32", compute_dtype="float32")
        model = build_model(cfg, Runtime.from_run(run))
        params = model.init(jax.random.PRNGKey(0))
        return ServeEngine(model, params, batch_size=4, max_len=160,
                           sl_granularity=8)

    def requests(n=24, seed=0):
        # skewed SL mix: mostly short prompts, a wide straggler every 4th
        # arrival — the FIFO-batching worst case (each chunk pads to it)
        rng = np.random.RandomState(seed)
        out = []
        for i in range(n):
            sl = 128 if i % 4 == 0 else int(rng.randint(5, 17))
            out.append(Request(
                prompt=rng.randint(1, 255, size=sl).astype(np.int32),
                max_new_tokens=int(rng.randint(2, 6))))
        return out

    n = 24
    print(f"\nserving-load drill: {n} requests, skewed SLs "
          f"(1-in-4 at 128, rest in [5, 16])")
    obs.event("serve_drill_start", n_requests=n)
    srv = obs.serve_http()

    base = run_to_completion(make_engine(), requests(n))
    sched = make_engine().serve(requests(n), policy=BucketAffinePolicy())

    scrape = urllib.request.urlopen(srv.url, timeout=5).read().decode()
    n_series = sum(1 for ln in scrape.splitlines()
                   if ln.startswith("serve_sched"))
    srv.close()

    for name, s in (("run-to-completion", base), ("sched", sched)):
        print(f"  {name:18s} waste={s.padding_waste:.3f} "
              f"grid_tput={s.grid_throughput:.4f} tokens={s.tokens_out} "
              f"prefills={s.prefills} decode_steps={s.decode_steps}")
    red = 1.0 - sched.padding_waste / base.padding_waste \
        if base.padding_waste else 0.0
    print(f"  padding-waste reduction: {100 * red:.1f}% "
          f"(acceptance bar: 25%)")
    print(f"  live scrape {srv.url}: {n_series} serve_sched series")

    ok = (sched.tokens_out == base.tokens_out
          and sched.padding_waste <= 0.75 * base.padding_waste
          and sched.grid_throughput > base.grid_throughput
          and n_series > 0)
    obs.event("serve_drill_end", ok=bool(ok), waste_base=base.padding_waste,
              waste_sched=sched.padding_waste, reduction=red,
              tokens=sched.tokens_out, scrape_series=n_series)
    print(f"  serving drill: {'OK' if ok else 'FAILED'}")
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--obs-dir", default=os.environ.get("REPRO_OBS_DIR"),
                    help="enable tracing/metrics/events, export here")
    ap.add_argument("--chaos", action="store_true",
                    default=bool(os.environ.get("REPRO_FAULTS")),
                    help="run the fault-injection recovery drill "
                         "(auto-on when REPRO_FAULTS is set)")
    ap.add_argument("--serve-sched", action="store_true",
                    help="run only the serving-load drill: SL-aware "
                         "continuous batching vs run-to-completion")
    args = ap.parse_args()
    if args.obs_dir:
        obs.enable(out_dir=args.obs_dir)

    if args.serve_sched:
        ok = serve_drill()
        obs.event("run_end", example="quickstart", ok=bool(ok))
        if args.obs_dir:
            paths = obs.export_all()
            print("\nobservability artifacts:")
            for kind, path in sorted(paths.items()):
                print(f"  {kind:13s} {path}")
        sys.exit(0 if ok else 1)

    setup = SETUPS["gnmt"]()
    rng = np.random.RandomState(0)
    sls = setup["dist"].sample(rng, 1280)
    plan = plan_epoch(sls, setup["batch_size"],
                      granularity=setup["granularity"])
    print(f"epoch: {plan.num_batches} iterations, "
          f"{len(set(map(int, plan.padded_sls)))} unique padded SLs")
    obs.event("run_start", example="quickstart", network="gnmt",
              iterations=plan.num_batches)

    print("profiling every unique SL (the expensive ground-truth pass)...")
    provider = WallclockProvider(setup["step_builder"], repeats=3)
    with obs.span("quickstart/profile_epoch"):
        log = epoch_log_from_plan(plan, provider)
    print(f"measured epoch time: {log.total_runtime:.2f}s")

    with obs.span("quickstart/select_seqpoints"):
        sp = select_seqpoints(log, error_threshold=0.02)
    print(f"\nSeqPoints: {sp.num_points} iterations (k={sp.k}) "
          f"-> projected {sp.predicted:.2f}s, error {100*sp.error:.2f}%")
    print(f"  SLs: {sp.seq_lens}")
    obs.event("seqpoints_selected", num_points=sp.num_points, k=sp.k,
              error=sp.error, converged=sp.meta.get("converged"))
    for name, fn in (("frequent", frequent), ("median", median),
                     ("worst", worst), ("prior", prior)):
        b = fn(log)
        print(f"  {name:9s}: {b.num_points:3d} iterations, "
              f"error {100*b.error:6.2f}%")
    red = plan.num_batches / sp.num_points
    print(f"\nprofiling reduction: {red:.0f}x fewer iterations "
          f"(paper reports 214x/345x at full dataset scale)")

    # live projection-error check: price every logged iteration by its
    # nearest SeqPoint and compare against the measured epoch total
    monitor = obs.ProjectionMonitor(sp)
    monitor.observe_log(log)
    rep = monitor.report()
    print(f"projection monitor: projected {rep.projected_total:.2f}s vs "
          f"measured {rep.measured_total:.2f}s "
          f"(rel error {100*rep.rel_error:.2f}%, "
          f"{len(rep.per_sl)} SLs tracked)")
    obs.event("projection_report", projected=rep.projected_total,
              measured=rep.measured_total, rel_error=rep.rel_error)

    if args.chaos:
        ok = chaos_drill()
        ok = elastic_drill() and ok
        if not ok:
            obs.event("run_end", example="quickstart", ok=False)
            if args.obs_dir:
                obs.export_all()
            sys.exit(1)

    obs.event("run_end", example="quickstart")
    if args.obs_dir:
        paths = obs.export_all()
        print("\nobservability artifacts:")
        for kind, path in sorted(paths.items()):
            print(f"  {kind:13s} {path}")


if __name__ == "__main__":
    main()
