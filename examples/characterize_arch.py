"""SeqPoint-accelerated characterization of an assigned architecture.

For a production (arch, mesh, batch) and a document-length distribution,
answering "what does a full variable-SL training epoch cost?" requires
compiling every unique padded SL — minutes of XLA time per SL at fleet
scale. This driver (1) selects SeqPoints from a *cheap analytic* runtime
proxy, (2) compiles ONLY the SeqPoint SLs on the production mesh, and
(3) projects epoch totals (time / FLOPs / HBM / collective bytes),
reporting the measured compile-time saving — SeqPoint's §VI-F claim
restated for the XLA era (DESIGN.md §2).

    PYTHONPATH=src python examples/characterize_arch.py \
        --arch qwen2-moe-a2.7b --samples 2048
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--samples", type=int, default=2048)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--max-sl", type=int, default=4096)
    ap.add_argument("--granularity", type=int, default=256)
    args = ap.parse_args()

    import dataclasses

    import jax

    from repro.configs import SINGLE_POD, ShapeConfig, StepKind, \
        get_model_config
    from repro.core import EpochLog, select_seqpoints
    from repro.data.batching import plan_epoch
    from repro.data.synthetic import lm_documents
    from repro.launch.dryrun import default_run, lower_cell, _reduced
    from repro.launch.mesh import make_mesh
    from repro.perfmodel.hlo import parse_collectives
    from repro.perfmodel.machine import TPU_V5E
    from repro.perfmodel.model_flops import model_flops, param_count

    cfg = get_model_config(args.arch)
    rng = np.random.RandomState(0)
    dist = lm_documents(args.max_sl)
    sls = dist.sample(rng, args.samples)
    plan = plan_epoch(sls, args.batch, granularity=args.granularity)
    uniq = sorted(set(int(s) for s in plan.padded_sls))
    print(f"{args.arch}: epoch of {plan.num_batches} iterations, "
          f"{len(uniq)} unique padded SLs {uniq[:5]}...{uniq[-3:]}")

    # (1) cheap analytic proxy for selection (no compiles)
    n_active = param_count(cfg, active=True)
    log = EpochLog()
    for sl in plan.padded_sls:
        t = 6 * n_active * args.batch * int(sl) / SINGLE_POD.num_devices \
            / TPU_V5E.peak_flops
        log.append(int(sl), t)
    sp = select_seqpoints(log, error_threshold=0.02)
    print(f"SeqPoints: {sp.num_points} of {len(uniq)} unique SLs "
          f"-> compile {sp.num_points} instead of {len(uniq)} shapes")

    # (2) compile only the SeqPoint SLs on the production mesh
    mesh = make_mesh(SINGLE_POD)
    per_sl = {}
    t0 = time.perf_counter()
    for sl in sp.seq_lens:
        shape = ShapeConfig(f"sl{sl}", seq_len=int(sl),
                            global_batch=args.batch, step=StepKind.TRAIN)
        rcfg = _reduced(cfg, 1)
        run = dataclasses.replace(
            default_run(rcfg, shape, SINGLE_POD), unroll_layers=1)
        compiled = lower_cell(rcfg, run, mesh, roofline=True).compile()
        ca = compiled.cost_analysis()
        n_periods = cfg.num_layers // cfg.interleave_period
        flops = float(ca.get("flops", 0.0)) * n_periods   # 1-period scaled
        coll = parse_collectives(compiled.as_text()).wire_bytes * n_periods
        per_sl[int(sl)] = {"flops": flops, "coll": coll,
                           "t": max(flops / TPU_V5E.peak_flops,
                                    coll / TPU_V5E.ici_bw)}
    compile_seconds = time.perf_counter() - t0

    # (3) project the epoch
    total_t = sp.project_total(lambda s: per_sl[int(s)]["t"])
    total_f = sp.project_total(lambda s: per_sl[int(s)]["flops"])
    est_full = compile_seconds / sp.num_points * len(uniq)
    print(f"projected epoch: {total_t:.1f}s/epoch roofline-bound, "
          f"{total_f:.3g} per-chip FLOPs")
    print(f"profiling cost: {compile_seconds:.0f}s for "
          f"{sp.num_points} compiles vs ~{est_full:.0f}s for all "
          f"{len(uniq)} unique SLs ({est_full/max(compile_seconds,1e-9):.1f}x"
          f" saved)")


if __name__ == "__main__":
    main()
