"""Benchmarks mirroring each paper table/figure (DESIGN.md §9).

Each function emits ``name,us_per_call,derived`` rows; `us_per_call` is the
relevant per-iteration time where meaningful (else 0), `derived` carries the
figure's headline quantity.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, reproduction


def sl_histogram(fast: bool) -> None:
    """Fig. 7: unique-SL histograms of the training sets."""
    for net in ("gnmt", "ds2"):
        r = reproduction(net, fast)
        hist = r["sl_histogram"]
        n_uniq = r["num_unique_sls"]
        frac = n_uniq / r["num_iterations"]
        emit(f"fig7_sl_histogram_{net}", 0.0,
             f"unique_sls={n_uniq} iterations={r['num_iterations']} "
             f"unique_frac={frac:.2f} "
             f"min={min(map(int, hist))} max={max(map(int, hist))}")


def runtime_vs_sl(fast: bool) -> None:
    """Fig. 9: per-iteration runtime vs SL (near-linear for RNNs)."""
    for net in ("gnmt", "ds2"):
        r = reproduction(net, fast)
        by_sl = {int(k): v for k, v in r["wallclock"]["runtime_by_sl"].items()}
        sls = np.array(sorted(by_sl))
        ts = np.array([by_sl[s] for s in sls])
        corr = float(np.corrcoef(sls, ts)[0, 1])
        slope = float(np.polyfit(sls, ts, 1)[0])
        emit(f"fig9_runtime_vs_sl_{net}", float(ts.mean() * 1e6),
             f"pearson_r={corr:.4f} us_per_sl={slope*1e6:.2f} "
             f"range=[{ts.min()*1e3:.1f},{ts.max()*1e3:.1f}]ms")


def profile_similarity(fast: bool) -> None:
    """Fig. 8: nearby SLs have similar kernel (HLO-op) distributions."""
    for net in ("gnmt", "ds2"):
        r = reproduction(net, fast)
        hists = r.get("op_histograms")
        if not hists:
            continue
        sls = sorted(int(k) for k in hists)
        keys = sorted({k for h in hists.values() for k in h})

        def vec(sl):
            h = hists[str(sl)] if str(sl) in hists else hists[sl]
            v = np.array([h.get(k, 0) for k in keys], float)
            return v / max(np.linalg.norm(v), 1e-12)

        near = float(vec(sls[0]) @ vec(sls[1]))
        far = float(vec(sls[0]) @ vec(sls[-1]))
        emit(f"fig8_profile_similarity_{net}", 0.0,
             f"cosine_near={near:.4f} cosine_far={far:.4f} "
             f"sls={sls[0]}/{sls[1]}/{sls[-1]}")


def projection_error(fast: bool) -> None:
    """Figs. 11/12: error projecting total training time (wallclock track
    = config#1 measured on this host; analytic track = configs #1-#5)."""
    for net in ("gnmt", "ds2"):
        r = reproduction(net, fast)
        for method, v in r["wallclock"]["methods"].items():
            emit(f"fig11_12_time_error_wallclock_{net}_{method}", 0.0,
                 f"error_pct={v['error_pct']:.3f} points={v['num_points']}")
        for method, v in r["analytic"]["methods"].items():
            emit(f"fig11_12_time_error_analytic_{net}_{method}", 0.0,
                 f"geomean_error_pct={v['geomean_time_error_pct']:.3f} "
                 f"points={v['num_points']}")


def sensitivity(fast: bool) -> None:
    """Figs. 13/14: per-SL speedup spread across hardware configs."""
    for net in ("gnmt", "ds2"):
        r = reproduction(net, fast)
        for cfgname, d in r["analytic"]["per_sl_speedup"].items():
            sp = np.array(list(d.values()))
            emit(f"fig13_14_sensitivity_{net}_{cfgname}", 0.0,
                 f"speedup_min={sp.min():.3f} max={sp.max():.3f} "
                 f"spread_pct={100*(sp.max()-sp.min())/sp.min():.1f}")


def speedup_projection(fast: bool) -> None:
    """Figs. 15/16: error projecting config#1 -> #c speedups."""
    for net in ("gnmt", "ds2"):
        r = reproduction(net, fast)
        for method, v in r["analytic"]["methods"].items():
            worst_pp = max(c["speedup_error_pp"]
                           for c in v["per_config"].values())
            geo = float(np.exp(np.mean(
                [np.log(max(c["speedup_error_pp"], 1e-3))
                 for k, c in v["per_config"].items() if k != "config1"])))
            emit(f"fig15_16_speedup_error_{net}_{method}", 0.0,
                 f"geomean_error_pp={geo:.3f} worst_pp={worst_pp:.3f}")


def profiling_speedup(fast: bool) -> None:
    """§VI-F: profiling-cost reduction (iterations + measured seconds)."""
    for net in ("gnmt", "ds2"):
        r = reproduction(net, fast)
        p = r["wallclock"]["profiling"]
        serial = p["full_seconds"] / max(p["seqpoint_seconds"], 1e-9)
        emit(f"sec6f_profiling_speedup_{net}", 0.0,
             f"iter_reduction={p['iter_reduction']:.1f}x "
             f"measured_seconds_reduction={serial:.1f}x "
             f"(full={p['full_seconds']:.1f}s "
             f"seqpoints={p['seqpoint_seconds']:.1f}s)")


def iteration_heterogeneity(fast: bool) -> None:
    """Fig. 4: per-iteration arch counters vary across iterations."""
    for net in ("gnmt", "ds2"):
        r = reproduction(net, fast)
        stats = r["analytic"]["per_sl_stats"]
        fl = np.array([v["flops"] for v in stats.values()])
        by = np.array([v["bytes"] for v in stats.values()])
        emit(f"fig4_heterogeneity_{net}", 0.0,
             f"flops_spread_pct={100*(fl.max()-fl.min())/fl.min():.0f} "
             f"bytes_spread_pct={100*(by.max()-by.min())/by.min():.0f}")


def gemm_dims(fast: bool) -> None:
    """Table I: the same GEMM's dims differ across SLs."""
    import re

    import jax

    from repro.core.reproduction import SETUPS
    setup = SETUPS["gnmt"]()
    dims = {}
    for sl in (16, 96):
        fn, args = setup["step_builder"](sl)
        txt = jax.jit(fn).lower(*args).compile().as_text()
        dots = re.findall(r"= f32\[([0-9,]+)\][^ ]* dot\(", txt)
        # largest three GEMM outputs — attention scores/context grow with SL
        dots = sorted(set(dots),
                      key=lambda d: -int(d.split(",")[0]) * int(
                          d.split(",")[-1]))[:3]
        dims[sl] = dots
    emit("table1_gemm_dims_gnmt", 0.0,
         f"sl16={dims[16]} sl96={dims[96]}")


ALL = [sl_histogram, runtime_vs_sl, profile_similarity, projection_error,
       sensitivity, speedup_projection, profiling_speedup,
       iteration_heterogeneity, gemm_dims]
