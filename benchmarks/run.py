"""Benchmark harness: one function per paper table/figure + system tables.

``python -m benchmarks.run [--fast]`` prints ``name,us_per_call,derived``
CSV. ``--fast`` uses reduced epochs (CI-sized); the full runs are what
EXPERIMENTS.md cites.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced epoch sizes (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filter on bench names")
    args = ap.parse_args()

    from benchmarks import dryrun_summary, kernels_bench, padding_waste, \
        paper_figures

    print("name,us_per_call,derived")
    groups = (paper_figures.ALL + kernels_bench.ALL + padding_waste.ALL
              + dryrun_summary.ALL)
    only = args.only.split(",") if args.only else None
    for fn in groups:
        if only and not any(o in fn.__name__ for o in only):
            continue
        try:
            fn(args.fast)
        except Exception as e:                      # noqa: BLE001
            print(f"BENCH_ERROR_{fn.__name__},0,{type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)


if __name__ == "__main__":
    main()
