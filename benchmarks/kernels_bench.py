"""Kernel microbenchmarks.

On this CPU container the Pallas kernels run in interpret mode (not
representative), so ``us_per_call`` times the jitted XLA reference path and
``derived`` carries the kernel's analytic TPU-side roofline time for the
same shape (197 TFLOP/s bf16 / 819 GB/s HBM)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.perfmodel.machine import TPU_V5E


def flash_attention_bench(fast: bool) -> None:
    from repro.kernels.flash_attention.ref import attention_ref

    bh, s, dh = 8, 1024 if fast else 2048, 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q, k, v = (jax.random.normal(ks[i], (bh, s, dh), jnp.bfloat16)
               for i in range(3))
    fn = jax.jit(lambda q, k, v: attention_ref(q, k, v, causal=True))
    us = timeit(fn, q, k, v)
    flops = 4 * bh * s * s * dh / 2          # causal
    hbm = 4 * bh * s * dh * 2
    t_tpu = TPU_V5E.step_time(flops, hbm, 0)
    emit("kernel_flash_attention_ref", us,
         f"tpu_roofline_us={t_tpu*1e6:.1f} flops={flops:.3g} shape=bh{bh}xS{s}xd{dh}")


def wkv6_bench(fast: bool) -> None:
    from repro.kernels.rwkv6_wkv.ref import wkv6_ref

    bh, s, dh = 8, 512 if fast else 1024, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r, k, v = (jax.random.normal(ks[i], (bh, s, dh)) for i in range(3))
    lw = -jnp.exp(jnp.clip(jax.random.normal(ks[3], (bh, s, dh)), -8, 0))
    u = jax.random.normal(ks[4], (bh, dh))
    fn = jax.jit(wkv6_ref)
    us = timeit(fn, r, k, v, lw, u)
    # chunked kernel flops: intra (C x C) + inter state updates
    c = 64
    flops = bh * (s / c) * (2 * c * c * dh * 2 + 2 * c * dh * dh * 2)
    hbm = 5 * bh * s * dh * 4
    emit("kernel_rwkv6_wkv_ref", us,
         f"tpu_roofline_us={TPU_V5E.step_time(flops, hbm, 0)*1e6:.1f} "
         f"shape=bh{bh}xS{s}xd{dh}")


def mamba_bench(fast: bool) -> None:
    from repro.kernels.mamba_scan.ref import mamba_scan_ref

    b, s, d, n = 2, 256 if fast else 512, 512, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 6)
    x = jax.random.normal(ks[0], (b, s, d))
    delta = jax.nn.softplus(jax.random.normal(ks[1], (b, s, d)) - 2)
    a = -jnp.exp(jax.random.normal(ks[2], (d, n)) * 0.3)
    bm, cm = (jax.random.normal(ks[i], (b, s, n)) for i in (3, 4))
    dd = jax.random.normal(ks[5], (d,))
    fn = jax.jit(mamba_scan_ref)
    us = timeit(fn, x, delta, a, bm, cm, dd)
    flops = 9 * b * s * d * n
    hbm = (2 * b * s * d + 2 * b * s * n) * 4
    emit("kernel_mamba_scan_ref", us,
         f"tpu_roofline_us={TPU_V5E.step_time(flops, hbm, 0)*1e6:.1f} "
         f"shape=B{b}xS{s}xD{d}xN{n}")


def lstm_bench(fast: bool) -> None:
    from repro.kernels.lstm_cell.ref import lstm_cell_ref

    b, d, h = 128, 512, 512
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    xh = jax.random.normal(ks[0], (b, d + h))
    w = jax.random.normal(ks[1], (d + h, h, 4)) * 0.1
    bias = jax.random.normal(ks[2], (h, 4)) * 0.1
    c = jax.random.normal(ks[3], (b, h))
    fn = jax.jit(lstm_cell_ref)
    us = timeit(fn, xh, w, bias, c)
    flops = 2 * b * (d + h) * 4 * h
    hbm = ((d + h) * 4 * h + b * (d + 2 * h)) * 4
    emit("kernel_lstm_cell_ref", us,
         f"tpu_roofline_us={TPU_V5E.step_time(flops, hbm, 0)*1e6:.1f} "
         f"shape=B{b}xD{d}xH{h}")


ALL = [flash_attention_bench, wkv6_bench, mamba_bench, lstm_bench]
