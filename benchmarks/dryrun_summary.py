"""Dry-run / roofline tables as benchmark rows (reads results/*.jsonl)."""
from __future__ import annotations

import argparse
import json
import os
import sys

from benchmarks.common import RESULTS_DIR, emit


def _read(name):
    path = os.path.join(RESULTS_DIR, name)
    if not os.path.exists(path):
        return []
    return [json.loads(l) for l in open(path)]


def compile_summary(fast: bool) -> None:
    for mesh, fname in (("16x16", "dryrun_compile_single.jsonl"),
                        ("2x16x16", "dryrun_compile_multi.jsonl")):
        recs = _read(fname)
        ok = sum(1 for r in recs if r["status"] == "ok")
        fits = sum(1 for r in recs if r["status"] == "ok"
                   and r.get("memory", {}).get("fits_v5e_16g_structural"))
        emit(f"dryrun_compile_{mesh}", 0.0,
             f"cells_ok={ok}/{len(recs)} fits_structural={fits}")
        for r in recs:
            if r["status"] != "ok":
                emit(f"dryrun_FAIL_{mesh}_{r['arch']}_{r['shape']}", 0.0,
                     r["error"][:120])


def roofline_summary(fast: bool) -> None:
    recs = _read("dryrun_roofline.jsonl")
    for r in recs:
        if r["status"] != "ok":
            emit(f"roofline_FAIL_{r['arch']}_{r['shape']}", 0.0,
                 r["error"][:120])
            continue
        t = r["terms"]
        step_s = max(t.values())
        emit(f"roofline_{r['arch']}_{r['shape']}", step_s * 1e6,
             f"dominant={r['dominant']} compute_s={t['compute_s']:.4f} "
             f"memory_s={t['memory_s']:.4f} "
             f"collective_s={t['collective_s']:.4f} "
             f"useful_flops_ratio={r['useful_flops_ratio']:.3f} "
             f"roofline_fraction={r['roofline_fraction']:.4f}")


def perf_summary(fast: bool) -> None:
    """Hillclimbed cells: baseline vs optimized (results/perf_*.json)."""
    for name in sorted(os.listdir(RESULTS_DIR)):
        if not name.startswith("perf_") or not name.endswith(".json"):
            continue
        with open(os.path.join(RESULTS_DIR, name)) as f:
            p = json.load(f)
        emit(f"perf_{p['cell']}", 0.0,
             f"baseline_bound_s={p['baseline']['bound_s']:.4f} "
             f"optimized_bound_s={p['optimized']['bound_s']:.4f} "
             f"speedup={p['speedup']:.2f}x "
             f"roofline_frac {p['baseline']['fraction']:.3f}"
             f"->{p['optimized']['fraction']:.3f}")


_PROJ_FILES = (("16x16", "dryrun_compile_single.jsonl"),
               ("2x16x16", "dryrun_compile_multi.jsonl"),
               ("roofline", "dryrun_roofline.jsonl"))


def projection_summary(fast: bool) -> float:
    """One row per cell: analytic-vs-measured collective bytes relative
    error (obs.projection). Returns the max *claimed-kind* error seen (the
    all-reduce residual the analytic model is accountable for; unclaimed
    ZeRO gathers and permutes stay visible in rel_error); the CLI
    entrypoint below turns a bound violation into a non-zero exit."""
    max_err = 0.0
    for tag, fname in _PROJ_FILES:
        for r in _read(fname):
            proj = r.get("projection")
            if r["status"] != "ok" or proj is None:
                continue
            err = float(proj.get("rel_error_claimed", proj["rel_error"]))
            max_err = max(max_err, err)
            emit(f"projection_{tag}_{r['arch']}_{r['shape']}", 0.0,
                 f"analytic_bytes={proj['analytic_wire_bytes']:.3e} "
                 f"measured_bytes={proj['measured_wire_bytes']:.3e} "
                 f"rel_error={float(proj['rel_error']):.4f} "
                 f"rel_error_claimed={err:.4f} "
                 f"rel_error_reduce={proj['rel_error_reduce']:.4f}")
    emit("projection_max_rel_error", 0.0,
         f"max_rel_error_claimed={max_err:.4f}")
    return max_err


ALL = [compile_summary, roofline_summary, perf_summary, projection_summary]


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--max-rel-error", type=float,
                    default=float(os.environ.get(
                        "REPRO_PROJECTION_ERROR_BOUND", "0.75")),
                    help="fail (exit 1) if any cell's analytic-vs-measured "
                         "all-reduce wire-bytes relative error exceeds this "
                         "(default 0.75 now that the analytic model knows "
                         "grad dtype, ZeRO micro-reduces, and the "
                         "spec-derived DP ring size — dense compile cells "
                         "sit under 0.4, MoE/roofline under 0.75; override "
                         "via REPRO_PROJECTION_ERROR_BOUND)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for fn in (compile_summary, roofline_summary, perf_summary):
        fn(False)
    max_err = projection_summary(False)
    if max_err > args.max_rel_error:
        print(f"projection error {max_err:.4f} exceeds bound "
              f"{args.max_rel_error:.4f}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
