"""Shared benchmark plumbing: CSV emission + cached reproduction results."""
from __future__ import annotations

import os
import sys
import time
from typing import Callable, Iterable, List, Tuple

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.3f},{derived}", flush=True)


def reproduction(network: str, fast: bool = False) -> dict:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.core.reproduction import run_reproduction

    samples = {"gnmt": 640, "ds2": 320} if fast else None
    return run_reproduction(
        network, samples=samples[network] if samples else None,
        tag="_fast" if fast else "")


def timeit(fn: Callable, *args, repeats: int = 3) -> float:
    """Median wall microseconds per call (after a warmup call)."""
    import jax
    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
