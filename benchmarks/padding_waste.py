"""Beyond-paper: SL-bucketed batching (the SeqPoint binning insight applied
to the data pipeline) — padding-FLOP savings."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.data.batching import plan_epoch
from repro.data.synthetic import IWSLT_LIKE, LIBRISPEECH_LIKE


def padding_waste(fast: bool) -> None:
    rng = np.random.RandomState(0)
    for name, dist, batch in (("iwslt", IWSLT_LIKE, 64),
                              ("librispeech", LIBRISPEECH_LIKE, 32)):
        sls = dist.sample(rng, 2000 if fast else 20000)
        rand = plan_epoch(sls, batch, granularity=8, bucketed=False, seed=1)
        buck = plan_epoch(sls, batch, granularity=8, bucketed=True, seed=1)
        emit(f"padding_waste_{name}", 0.0,
             f"random={100*rand.padding_waste():.1f}% "
             f"bucketed={100*buck.padding_waste():.1f}% "
             f"flops_saved={100*(rand.padding_waste()-buck.padding_waste()):.1f}pp")


ALL = [padding_waste]
