"""Train/serve step builders: the functions jit/lowered by launch + trainer.

``build_train_step`` returns a pure ``(train_state, batch) -> (train_state,
metrics)`` with optional microbatch gradient accumulation (scan over
microbatches — compute/comm overlap is left to XLA's latency-hiding
scheduler; each microbatch's gradient all-reduce can overlap the next
microbatch's backward).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig, StepKind
from repro.dist.compression import (
    compress_grads,
    decompress_grads,
    init_residual,
)
from repro.models.model_zoo import Model
from repro.train.optimizer import (
    OptState,
    adamw_update,
    init_opt_state,
    lr_schedule,
)

Params = Any


class TrainState(NamedTuple):
    params: Params
    opt: OptState
    # error-feedback residual for compressed DP gradients; None when the
    # compression method carries no state (tree structure is step-invariant,
    # and None leaves vanish in path-flattened checkpoints)
    ef: Any = None


def init_train_state(model: Model, run: RunConfig, rng: jax.Array
                     ) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params,
                      opt=init_opt_state(params, run.optimizer),
                      ef=init_residual(params,
                                       run.optimizer.grad_compression))


def build_train_step(model: Model, run: RunConfig, total_steps: int = 10_000
                     ) -> Callable[[TrainState, Dict[str, jax.Array]],
                                   Tuple[TrainState, Dict[str, jax.Array]]]:
    lr_fn = lr_schedule(run.optimizer, total_steps)
    nmicro = max(run.microbatches, 1)
    # dry-run roofline mode unrolls the accumulation scan so cost_analysis
    # counts every microbatch (DESIGN.md §6)
    scan_unroll = nmicro if run.unroll_layers else 1

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if nmicro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((nmicro, x.shape[0] // nmicro)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                (loss_a, grads_a) = carry
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mb)
                grads = jax.tree.map(jnp.add, grads_a, grads)
                return (loss_a + loss, grads), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), metrics = jax.lax.scan(
                acc, (jnp.zeros((), jnp.float32), zeros), micro,
                unroll=scan_unroll)
            loss = loss / nmicro
            grads = jax.tree.map(lambda g: g / nmicro, grads)
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        # compressed DP all-reduce: quantize (grads + residual) to the wire
        # format, apply the decompressed gradient, carry the new residual.
        # The compress/decompress pair brackets the cross-replica reduction
        # under SPMD; numerically it is replica-identical, so it also runs
        # (and is tested) on a single device.
        method = run.optimizer.grad_compression
        ef_new = state.ef
        if method != "none":
            if state.ef is not None:
                grads = jax.tree.map(jnp.add, grads, state.ef)
            wire, err = compress_grads(grads, method)
            grads = decompress_grads(wire, method, grads)
            if state.ef is not None:
                ef_new = err

        lr = lr_fn(state.opt.step)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, run.optimizer, lr)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return TrainState(new_params, new_opt, ef_new), metrics

    return train_step


def build_serve_step(model: Model, run: RunConfig, kind: StepKind):
    """prefill: batch -> (logits, caches). decode: one-token step."""
    if kind == StepKind.PREFILL:
        def prefill(params, batch):
            return model.prefill(params, batch)
        return prefill

    def decode(params, caches, token, cache_index):
        return model.decode_step(params, caches, token, cache_index)
    return decode
