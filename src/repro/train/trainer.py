"""Training loop: auto-resume, async checkpoints, straggler detection,
SeqPoint epoch logging as a first-class hook.

The trainer logs every iteration's (padded SL, wallclock) into an
``EpochLog`` — after one epoch, ``seqpoints()`` hands back the
representative iterations, which is how a fleet user would profile a new
hardware/software config for this exact (model, dataset, batch-size)
combination without re-running the epoch (paper §V-C step 1 integrated at
the point the data already flows).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, Optional

import jax
import numpy as np

from repro import obs
from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import RunConfig
from repro.core.profile import EpochLog
from repro.dist.compression import dp_grad_wire_bytes
from repro.dist.sharding import tp_activation_wire_bytes
from repro.core.seqpoint import SeqPointSet, select_seqpoints
from repro.data.batching import DataIterator
from repro.models.model_zoo import Model
from repro.train.train_step import TrainState, build_train_step, \
    init_train_state


@dataclass
class TrainerReport:
    steps: int = 0
    resumed_from: Optional[int] = None
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    stragglers: int = 0
    epoch_log: Optional[EpochLog] = None


class Trainer:
    def __init__(self, model: Model, run: RunConfig, data: DataIterator, *,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 straggler_factor: float = 3.0, total_steps: int = 1000):
        self.model = model
        self.run = run
        self.data = data
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.step_fn = jax.jit(build_train_step(model, run, total_steps),
                               donate_argnums=0)
        self.epoch_log = EpochLog(meta={"model": run.model.name})

    def init_or_resume(self, rng: jax.Array) -> tuple[TrainState, int]:
        state = init_train_state(self.model, self.run, rng)
        start = 0
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            state, extra = self.ckpt.restore(state)
            start = int(extra.get("step", self.ckpt.latest_step()))
            if "data_state" in extra:
                self.data.restore(extra["data_state"])
        return state, start

    def train(self, num_steps: int, rng: Optional[jax.Array] = None
              ) -> TrainerReport:
        rng = jax.random.PRNGKey(self.run.seed) if rng is None else rng
        state, start = self.init_or_resume(rng)
        report = TrainerReport(resumed_from=start or None)
        it: Iterator = iter(self.data)
        # per-step DP gradient wire bytes are SL-independent (one param-sized
        # all-reduce); TP activation bytes scale with SL — both go into
        # EpochLog.stats so SeqPoint projects communication alongside compute
        dp_deg = self.run.mesh.num_devices \
            if self.run.parallelism == "dp_only" else self.run.mesh.data_degree
        tp_deg = self.run.mesh.model_degree \
            if self.run.parallelism == "tp" else 1
        dp_bytes = dp_grad_wire_bytes(
            state.params, self.run.optimizer.grad_compression, dp_deg)
        obs.event("train_start", model=self.run.model.name, start_step=start,
                  num_steps=num_steps, dp_degree=dp_deg, tp_degree=tp_deg)
        mreg = obs.metrics
        sl_times: Dict[int, list] = {}
        for step in range(start, start + num_steps):
            with obs.span("train/step", step=step) as step_span:
                with obs.span("train/data_fetch"):
                    tokens, labels, sl = next(it)
                    batch = {"tokens": jax.numpy.asarray(tokens),
                             "labels": jax.numpy.asarray(labels)}
                step_span.set(sl=sl)
                t0 = time.perf_counter()
                with obs.span("train/step_fn", sl=sl):
                    state, metrics = self.step_fn(state, batch)
                with obs.span("train/block_until_ready"):
                    jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                # straggler mitigation: per-SL baseline — a step far beyond
                # the running median of its padded SL marks a straggler (on
                # real fleets this triggers hot-spare promotion; here we
                # count + log). SLs unseen so far fall back to the all-SL
                # median.
                baseline_pool = sl_times.get(sl) or report.step_times
                if baseline_pool:
                    baseline = float(np.median(baseline_pool))
                    if dt > self.straggler_factor * baseline:
                        report.stragglers += 1
                        mreg.counter("train_stragglers_total").inc()
                        obs.event("straggler", step=step, sl=sl, dt=dt,
                                  baseline=baseline,
                                  factor=self.straggler_factor)
                sl_times.setdefault(sl, []).append(dt)
                report.losses.append(float(metrics["loss"]))
                report.step_times.append(dt)
                tp_bytes = tp_activation_wire_bytes(
                    self.run.model, self.run.shape.global_batch, sl, tp_deg)
                self.epoch_log.append(sl, dt, dp_wire_bytes=dp_bytes,
                                      tp_wire_bytes=tp_bytes)
                mreg.counter("train_steps_total").inc()
                mreg.histogram("train_step_time_s", sl=sl).observe(dt)
                mreg.histogram("train_padded_sl").observe(sl)
                mreg.gauge("train_dp_wire_bytes").set(dp_bytes)
                mreg.histogram("train_tp_wire_bytes", sl=sl).observe(tp_bytes)
                if self.ckpt is not None and (step + 1) % self.ckpt_every == 0:
                    with obs.span("train/checkpoint_async", step=step + 1):
                        self.ckpt.save_async(
                            step + 1, state,
                            extra={"step": step + 1,
                                   "data_state": self.data.state()})
                    obs.event("checkpoint", step=step + 1, mode="async")
        if self.ckpt is not None:
            with obs.span("train/checkpoint_final", step=start + num_steps):
                self.ckpt.wait()
                self.ckpt.save(start + num_steps, state,
                               extra={"step": start + num_steps,
                                      "data_state": self.data.state()})
            obs.event("checkpoint", step=start + num_steps, mode="final")
        report.steps = num_steps
        report.epoch_log = self.epoch_log
        obs.event("train_end", steps=num_steps, stragglers=report.stragglers,
                  total_runtime=self.epoch_log.total_runtime)
        return report

    def seqpoints(self, **kw) -> SeqPointSet:
        return select_seqpoints(self.epoch_log, **kw)
