"""Training loop: auto-resume, async checkpoints, straggler detection,
SeqPoint epoch logging as a first-class hook — hardened for fleet faults.

The trainer logs every iteration's (padded SL, wallclock) into an
``EpochLog`` — after one epoch, ``seqpoints()`` hands back the
representative iterations, which is how a fleet user would profile a new
hardware/software config for this exact (model, dataset, batch-size)
combination without re-running the epoch (paper §V-C step 1 integrated at
the point the data already flows).

That projection is only trustworthy if the log survives real fleet
conditions, so the step loop is wrapped in a recovery ladder
(``repro.resilience``):

* transient data/checkpoint faults retry with backoff;
* a NaN/inf or diverging loss rolls back to the last good checkpoint —
  restoring params, optimizer, data-iterator position *and* the partial
  EpochLog — and a batch that fails repeatedly is skipped as poison;
* a preemption writes an emergency checkpoint pointing at the interrupted
  batch, so the resumed process replays it and the stitched EpochLog (and
  hence ``select_seqpoints``) matches the fault-free run bit-for-bit;
* a confirmed peer loss (``resilience.elastic``) checkpoints, shrinks the
  mesh over the surviving hosts, re-shards the restored state, and resumes
  in-process — the fourth recovery tier;
* a per-SL running-median watchdog flags stragglers (and injected ones).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro import obs
from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import RunConfig
from repro.core.profile import EpochLog
from repro.dist.compression import dp_grad_wire_bytes
from repro.dist.sharding import tp_activation_wire_bytes
from repro.core.seqpoint import SeqPointSet, select_seqpoints
from repro.data.batching import DataIterator
from repro.models.model_zoo import Model
from repro.resilience import elastic, faults
from repro.resilience.elastic import ClusterMonitor, PeerLossFault
from repro.resilience.guards import (
    DivergenceDetector,
    GuardViolation,
    StepTimeWatchdog,
    check_finite,
)
from repro.resilience.faults import PreemptionFault, TransientFault
from repro.resilience.recovery import (
    BatchSkipList,
    RecoveryPolicy,
    pack_train_extra,
    retry_with_backoff,
    unpack_train_extra,
)
from repro.train.train_step import TrainState, build_train_step, \
    init_train_state


@dataclass
class TrainerReport:
    steps: int = 0
    resumed_from: Optional[int] = None
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    stragglers: int = 0
    epoch_log: Optional[EpochLog] = None
    # resilience accounting
    preempted: bool = False          # train() returned early; resume to finish
    rollbacks: int = 0
    guard_violations: int = 0
    skipped_batches: int = 0
    remeshes: int = 0                # tier-4 elastic re-meshes taken
    lost_hosts: list = field(default_factory=list)


class Trainer:
    def __init__(self, model: Model, run: RunConfig, data: DataIterator, *,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 50,
                 straggler_factor: float = 3.0, total_steps: int = 1000,
                 policy: Optional[RecoveryPolicy] = None,
                 cluster: Optional[ClusterMonitor] = None,
                 timer: Callable[[], float] = time.perf_counter):
        self.model = model
        self.run = run
        self.data = data
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.policy = policy or RecoveryPolicy()
        self.cluster = cluster or ClusterMonitor.from_mesh(run.mesh)
        self.skiplist = BatchSkipList(
            skip_after=self.policy.skip_after_failures)
        self.timer = timer
        self.watchdog = StepTimeWatchdog(factor=straggler_factor)
        self.divergence = DivergenceDetector(
            ratio=self.policy.divergence_ratio,
            patience=self.policy.divergence_patience)
        self.step_fn = jax.jit(build_train_step(model, run, total_steps),
                               donate_argnums=0)
        self.epoch_log = EpochLog(meta={"model": run.model.name})

    # ------------------------------------------------------------------
    def _extra(self, step: int) -> dict:
        return pack_train_extra(step, self.data.state(), self.epoch_log,
                                self.skiplist)

    def _retry(self, fn, label: str):
        return retry_with_backoff(
            fn, retries=self.policy.max_retries,
            base_delay=self.policy.backoff_base_s,
            factor=self.policy.backoff_factor,
            max_delay_s=self.policy.max_delay_s,
            jitter_frac=self.policy.jitter_frac,
            jitter_seed=self.policy.jitter_seed, label=label)

    def init_or_resume(self, rng: jax.Array) -> tuple[TrainState, int]:
        state = init_train_state(self.model, self.run, rng)
        start = 0
        if self.ckpt is not None and self.ckpt.latest_step() is not None:
            state, extra = self._retry(lambda: self.ckpt.restore(state),
                                       label="ckpt_restore")
            start, data_state, log, skip_state = unpack_train_extra(extra)
            if data_state is not None:
                self.data.restore(data_state)
            if log is not None:
                self.epoch_log = log
            # a poison batch stays poison across process restarts — the
            # resumed process must not pay the discovery rollbacks again
            self.skiplist.restore(skip_state)
        return state, start

    def _comm_profile(self, state: TrainState) -> Tuple[int, int, float]:
        """(dp_degree, tp_degree, per-step DP grad wire bytes) for the
        *current* mesh — recomputed after an elastic re-mesh shrinks DP."""
        dp_deg = self.run.mesh.num_devices \
            if self.run.parallelism == "dp_only" else self.run.mesh.data_degree
        tp_deg = self.run.mesh.model_degree \
            if self.run.parallelism == "tp" else 1
        dp_bytes = dp_grad_wire_bytes(
            state.params, self.run.optimizer.grad_compression, dp_deg)
        return dp_deg, tp_deg, dp_bytes

    # ------------------------------------------------------------------
    def train(self, num_steps: int, rng: Optional[jax.Array] = None
              ) -> TrainerReport:
        rng = jax.random.PRNGKey(self.run.seed) if rng is None else rng
        state, start = self.init_or_resume(rng)
        report = TrainerReport(resumed_from=start or None)
        it: Iterator = iter(self.data)
        # per-step DP gradient wire bytes are SL-independent (one param-sized
        # all-reduce); TP activation bytes scale with SL — both go into
        # EpochLog.stats so SeqPoint projects communication alongside compute
        dp_deg, tp_deg, dp_bytes = self._comm_profile(state)
        obs.event("train_start", model=self.run.model.name, start_step=start,
                  num_steps=num_steps, dp_degree=dp_deg, tp_degree=tp_deg)
        mreg = obs.metrics
        skiplist = self.skiplist
        rollbacks = 0
        end = start + num_steps
        step = start
        # rollback safety net: guarantee a restorable checkpoint exists
        # before the first optimizer step can fail
        if self.ckpt is not None and self.ckpt.latest_step() is None:
            self._retry(lambda: self.ckpt.save(start, state,
                                               extra=self._extra(start)),
                        label="ckpt_save")
            obs.event("checkpoint", step=start, mode="initial")
        while step < end:
            # iterator position BEFORE the fetch: the identity of the batch
            # about to run, and the resume point if this step is preempted
            pre_fetch = self.data.state()
            batch_key = (pre_fetch["epoch"], pre_fetch["batch_index"])
            if skiplist.should_skip(batch_key):
                next(it)                              # discard poison batch
                report.skipped_batches += 1
                mreg.counter("train_skipped_batches_total").inc()
                obs.event("poison_batch_skipped", step=step,
                          epoch=batch_key[0], batch_index=batch_key[1])
                continue
            new_state = None
            try:
                # heartbeat interval: raises PeerLossFault once the tracker
                # confirms a host lost (tier-4 re-mesh arm below)
                self.cluster.pulse(step)
                with obs.span("train/step", step=step) as step_span:
                    with obs.span("train/data_fetch"):
                        def fetch():
                            faults.fire("data_fetch", step)
                            return next(it)
                        tokens, labels, sl = self._retry(fetch,
                                                         label="data_fetch")
                        batch = {"tokens": jax.numpy.asarray(tokens),
                                 "labels": jax.numpy.asarray(labels)}
                    step_span.set(sl=sl)
                    faults.fire("preempt", step)
                    t0 = self.timer()
                    with obs.span("train/step_fn", sl=sl):
                        new_state, metrics = self.step_fn(state, batch)
                    with obs.span("train/block_until_ready"):
                        jax.block_until_ready(metrics["loss"])
                    dt = self.timer() - t0
                    dt += faults.delay("straggler", step)
                    loss = faults.corrupt("nan_loss", step,
                                          float(metrics["loss"]))
                    check_finite(loss, name="loss", step=step)
                    if self.policy.check_grads and "grad_norm" in metrics:
                        check_finite(float(metrics["grad_norm"]),
                                     name="grad_norm", step=step)
                    self.divergence.update(loss, step=step)
            except PreemptionFault:
                return self._handle_preemption(step, start, state,
                                               pre_fetch, report)
            except PeerLossFault as e:
                mreg.counter("train_peer_losses_total").inc(len(e.hosts))
                obs.event("peer_lost", step=step, hosts=sorted(e.hosts),
                          tick=e.tick)
                if self.ckpt is None \
                        or report.remeshes >= self.policy.max_remeshes:
                    raise
                state, step = self._remesh(e, step, start, state,
                                           pre_fetch, report)
                dp_deg, tp_deg, dp_bytes = self._comm_profile(state)
                it = iter(self.data)  # regenerate from restored position
                continue
            except GuardViolation as e:
                report.guard_violations += 1
                mreg.counter("train_guard_violations_total").inc()
                obs.event("guard_violation", step=step, error=str(e),
                          epoch=batch_key[0], batch_index=batch_key[1])
                if self.ckpt is None or rollbacks >= self.policy.max_rollbacks:
                    raise
                rollbacks += 1
                report.rollbacks += 1
                now_poison = skiplist.record_failure(batch_key)
                state, step = self._rollback(
                    new_state if new_state is not None else state,
                    start, report, poison=now_poison)
                it = iter(self.data)      # regenerate from restored position
                continue
            # -- step accepted ------------------------------------------
            state = new_state
            verdict = self.watchdog.observe(sl, dt)
            if verdict.is_straggler:
                report.stragglers += 1
                mreg.counter("train_stragglers_total").inc()
                obs.event("straggler", step=step, sl=sl, dt=dt,
                          baseline=verdict.baseline,
                          factor=self.watchdog.factor)
            report.losses.append(loss)
            report.step_times.append(dt)
            tp_bytes = tp_activation_wire_bytes(
                self.run.model, self.run.shape.global_batch, sl, tp_deg)
            self.epoch_log.append(sl, dt, dp_wire_bytes=dp_bytes,
                                  tp_wire_bytes=tp_bytes)
            mreg.counter("train_steps_total").inc()
            mreg.histogram("train_step_time_s", sl=sl).observe(dt)
            mreg.histogram("train_padded_sl").observe(sl)
            mreg.gauge("train_dp_wire_bytes").set(dp_bytes)
            mreg.histogram("train_tp_wire_bytes", sl=sl).observe(tp_bytes)
            step += 1
            if self.ckpt is not None and step % self.ckpt_every == 0:
                self._save_periodic(step, state)
        if self.ckpt is not None:
            with obs.span("train/checkpoint_final", step=end):
                self._wait_ckpt()
                self._retry(lambda: self.ckpt.save(end, state,
                                                   extra=self._extra(end)),
                            label="ckpt_save")
            obs.event("checkpoint", step=end, mode="final")
        report.steps = num_steps
        report.epoch_log = self.epoch_log
        obs.event("train_end", steps=num_steps, stragglers=report.stragglers,
                  rollbacks=report.rollbacks,
                  skipped_batches=report.skipped_batches,
                  total_runtime=self.epoch_log.total_runtime)
        return report

    # ------------------------------------------------------------------
    def _wait_ckpt(self) -> None:
        """Drain the async writer; a surfaced background failure must not
        abort recovery (the event is already emitted at capture time)."""
        try:
            self.ckpt.wait()
        except (TransientFault, OSError):
            pass

    def _save_periodic(self, step: int, state: TrainState) -> None:
        with obs.span("train/checkpoint_async", step=step):
            try:
                self.ckpt.save_async(step, state, extra=self._extra(step))
            except (TransientFault, OSError) as e:
                # either the previous background write failed (surfaced by
                # save_async's wait) or the snapshot itself did — fall back
                # to a synchronous retried save so the rollback target
                # stays fresh
                obs.event("ckpt_save_error", step=step, error=repr(e))
                self._retry(lambda: self.ckpt.save(step, state,
                                                   extra=self._extra(step)),
                            label="ckpt_save")
        obs.event("checkpoint", step=step, mode="async")

    def _rollback(self, like: TrainState, start: int, report: TrainerReport,
                  *, poison: bool) -> Tuple[TrainState, int]:
        """Restore the last good checkpoint (params, opt, iterator position,
        partial EpochLog) and truncate the report to match."""
        with obs.span("train/rollback"):
            self._wait_ckpt()
            state, extra = self._retry(
                lambda: self.ckpt.restore(like, fallback=True),
                label="ckpt_restore")
            # NOTE: the skip list is deliberately NOT restored here — the
            # checkpoint predates the failures just recorded, and merging
            # an older snapshot must never undo in-memory poison status
            ckpt_step, data_state, log, _ = unpack_train_extra(extra)
            if data_state is not None:
                self.data.restore(data_state)
            if log is not None:
                self.epoch_log = log
            done = max(ckpt_step - start, 0)
            del report.losses[done:]
            del report.step_times[done:]
            self.divergence.reset()
        obs.metrics.counter("train_rollbacks_total").inc()
        obs.event("rollback", to_step=ckpt_step, poison_batch=poison)
        return state, ckpt_step

    def _remesh(self, e: PeerLossFault, step: int, start: int,
                state: TrainState, pre_fetch_state: Dict[str, int],
                report: TrainerReport) -> Tuple[TrainState, int]:
        """Tier 4: elastic re-mesh after a confirmed peer loss.

        Checkpoint (pinned at the batch about to run), shrink the mesh's
        data axis past the dead hosts, restore + re-shard onto the
        survivors, and resume in-process. The restored iterator position
        and partial EpochLog make the replayed steps re-log identical
        (sl, runtime) records, so SeqPoint selection survives the shrink;
        only the communication stats (dp_wire_bytes) change with the
        smaller DP degree, as they physically must.
        """
        lost = sorted(set(e.hosts) | self.cluster.dead_hosts)
        with obs.span("train/remesh", step=step, lost=lost):
            # pin the survivors' state before touching the mesh: if the
            # shrink itself fails we can still resume from here
            self._wait_ckpt()
            extra = pack_train_extra(step, pre_fetch_state, self.epoch_log,
                                     self.skiplist)
            self._retry(lambda: self.ckpt.save(step, state, extra=extra),
                        label="ckpt_save")
            obs.event("checkpoint", step=step, mode="remesh")
            # shrink: raises ClusterFailure when nothing survives
            new_mesh, _ = self.cluster.domains.surviving_mesh(lost)
            self.cluster = self.cluster.after_loss(e.hosts)
            self.run = dataclasses.replace(self.run, mesh=new_mesh)
            state, extra = self._retry(
                lambda: self.ckpt.restore(state, fallback=True),
                label="ckpt_restore")
            ckpt_step, data_state, log, skip_state = unpack_train_extra(extra)
            if data_state is not None:
                self.data.restore(data_state)
            if log is not None:
                self.epoch_log = log
            self.skiplist.restore(skip_state)
            state, n_sharded = elastic.reshard_state(state, self.run)
            done = max(ckpt_step - start, 0)
            del report.losses[done:]
            del report.step_times[done:]
            self.divergence.reset()
        report.remeshes += 1
        report.lost_hosts.extend(lost)
        mreg = obs.metrics
        mreg.counter("train_remeshes_total").inc()
        mreg.gauge("cluster_healthy_hosts").set(len(self.cluster.hosts))
        mreg.gauge("train_dp_degree").set(new_mesh.data_degree)
        obs.event("remesh", step=ckpt_step, lost_hosts=lost,
                  new_shape=list(new_mesh.shape),
                  data_degree=new_mesh.data_degree,
                  surviving_hosts=list(self.cluster.hosts),
                  resharded_params=n_sharded)
        return state, ckpt_step

    def _handle_preemption(self, step: int, start: int, state: TrainState,
                           pre_fetch_state: Dict[str, int],
                           report: TrainerReport) -> TrainerReport:
        """Graceful drain on preemption: emergency checkpoint pointing at
        the interrupted batch, then hand back a partial report. A fresh
        Trainer resumes at exactly this batch and the stitched run is
        indistinguishable from an uninterrupted one."""
        report.preempted = True
        report.steps = step - start
        report.epoch_log = self.epoch_log
        obs.metrics.counter("train_preemptions_total").inc()
        if self.ckpt is not None:
            with obs.span("train/checkpoint_preempt", step=step):
                self._wait_ckpt()
                extra = pack_train_extra(step, pre_fetch_state,
                                         self.epoch_log, self.skiplist)
                self._retry(lambda: self.ckpt.save(step, state, extra=extra),
                            label="ckpt_save")
            obs.event("checkpoint", step=step, mode="preempt")
        obs.event("preempted", step=step, completed=step - start,
                  can_resume=self.ckpt is not None)
        return report

    def seqpoints(self, **kw) -> SeqPointSet:
        return select_seqpoints(self.epoch_log, **kw)
