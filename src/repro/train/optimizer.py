"""AdamW with configurable moment dtype + global-norm clipping + schedule.

Moments inherit the parameter's sharding (same tree structure), so FSDP
configs automatically get ZeRO-sharded optimizer state. ``moment_dtype=
bfloat16`` halves optimizer HBM — required for deepseek-v3-scale cells
(DESIGN.md §8.4).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig

Params = Any


class OptState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


def init_opt_state(params: Params, cfg: OptimizerConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    m=jax.tree.map(zeros, params),
                    v=jax.tree.map(zeros, params))


def lr_schedule(cfg: OptimizerConfig, total_steps: int
                ) -> Callable[[jax.Array], jax.Array]:
    def fn(step: jax.Array) -> jax.Array:
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return cfg.lr * warm * (0.1 + 0.9 * cos)
    return fn


def global_norm(tree: Params) -> jax.Array:
    sq = jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.zeros((), jnp.float32)))


def _decayable(path) -> bool:
    last = str(path[-1].key) if hasattr(path[-1], "key") else str(path[-1])
    return not any(t in last for t in ("norm", "ln_", "bias", "b_", "mu_",
                                       "w0", "dt_bias"))


def adamw_update(grads: Params, state: OptState, params: Params,
                 cfg: OptimizerConfig, lr: jax.Array
                 ) -> Tuple[Params, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.ones(())
    step = state.step + 1
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    # Update arithmetic runs in the moment dtype for bf16-moment configs
    # (halves the elementwise-chain temporaries; >100B models only —
    # DESIGN.md §8.4). Variance epsilon guards bf16 sqrt.
    cdt = jnp.float32 if mdt == jnp.float32 else jnp.bfloat16

    def upd(path, p, g, m, v):
        g = g.astype(cdt) * scale.astype(cdt)
        mn = b1 * m.astype(cdt) + (1 - b1) * g
        vn = b2 * v.astype(cdt) + (1 - b2) * jnp.square(g)
        mhat = mn / bc1.astype(cdt)
        vhat = vn / bc2.astype(cdt)
        eps = cfg.eps if cdt == jnp.float32 else max(cfg.eps, 1e-5)
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if cfg.weight_decay and _decayable(path):
            delta = delta + cfg.weight_decay * p.astype(cdt)
        new_p = p.astype(cdt) - lr.astype(cdt) * delta
        return (new_p.astype(p.dtype), mn.astype(mdt), vn.astype(mdt))

    out = jax.tree_util.tree_map_with_path(upd, params, grads,
                                           state.m, state.v)
    outer = jax.tree_util.tree_structure(params)
    inner = jax.tree_util.tree_structure((0, 0, 0))
    new_p, new_m, new_v = jax.tree_util.tree_transpose(outer, inner, out)
    return new_p, OptState(step=step, m=new_m, v=new_v), \
        {"grad_norm": gnorm, "lr": lr}
