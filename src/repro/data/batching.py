"""Batch formation over variable-length sequences.

The paper's key mechanism (§IV-B1): a batch adopts the *maximum* SL of its
members and pads the rest, so per-iteration cost is keyed by that padded SL.
``granularity`` rounds batch SLs up to a multiple (real frameworks pad to
tile multiples; it also bounds the unique-SL count).

``bucketed=True`` is the beyond-paper optimization the SL-binning insight
suggests: draw each batch from one SL bucket so padding waste shrinks; the
saved-FLOPs are quantified in benchmarks/padding_waste.py.

The iterator is deterministic and checkpointable (``state()`` /
``from_state``) for fault-tolerant training.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.data.synthetic import SLDistribution, sample_tokens


def pad_to(sl: int, granularity: int) -> int:
    return int(-(-sl // granularity) * granularity)


@dataclass
class BatchPlan:
    """The epoch's batch schedule: per-batch padded SL + member lengths."""

    padded_sls: np.ndarray          # (num_batches,)
    member_sls: List[np.ndarray]    # raw lengths per batch
    batch_size: int

    @property
    def num_batches(self) -> int:
        return len(self.padded_sls)

    def padding_waste(self) -> float:
        """Fraction of token slots that are padding."""
        total = sum(int(p) * self.batch_size for p in self.padded_sls)
        real = sum(int(m.sum()) for m in self.member_sls)
        return 1.0 - real / max(total, 1)


def plan_epoch(sls: np.ndarray, batch_size: int, *, granularity: int = 8,
               bucketed: bool = False, sort_first: bool = False,
               seed: int = 0) -> BatchPlan:
    """Form an epoch's batches from sample lengths.

    ``sort_first`` models DS2's sorted first epoch (paper §VI-D: the
    artifact that made `prior` accidentally accurate on DS2).
    ``bucketed`` groups similar SLs per batch (beyond-paper).
    """
    rng = np.random.RandomState(seed)
    order = np.argsort(sls, kind="stable") if (sort_first or bucketed) \
        else rng.permutation(len(sls))
    sls = np.asarray(sls)[order]
    n_full = len(sls) // batch_size * batch_size
    batches = sls[:n_full].reshape(-1, batch_size)
    if bucketed and not sort_first:
        # batches are SL-homogeneous; shuffle batch order for training
        batches = batches[rng.permutation(len(batches))]
    padded = np.array([pad_to(int(b.max()), granularity) for b in batches])
    return BatchPlan(padded_sls=padded,
                     member_sls=[b.copy() for b in batches],
                     batch_size=batch_size)


@dataclass
class IteratorState:
    epoch: int
    batch_index: int
    seed: int


class DataIterator:
    """Deterministic, resumable iterator yielding (tokens, labels, seq_len).

    Data-parallel shards slice the batch dimension by (shard_id,
    num_shards); the SL schedule is identical across shards so all shards
    compile/execute the same padded shapes in lockstep (straggler-free by
    construction).
    """

    def __init__(self, dist: SLDistribution, *, samples_per_epoch: int,
                 batch_size: int, vocab_size: int, granularity: int = 8,
                 bucketed: bool = False, sort_first_epoch: bool = False,
                 seed: int = 0, shard_id: int = 0, num_shards: int = 1):
        assert batch_size % num_shards == 0
        self.dist = dist
        self.samples_per_epoch = samples_per_epoch
        self.batch_size = batch_size
        self.vocab_size = vocab_size
        self.granularity = granularity
        self.bucketed = bucketed
        self.sort_first_epoch = sort_first_epoch
        self.seed = seed
        self.shard_id = shard_id
        self.num_shards = num_shards
        self._state = IteratorState(epoch=0, batch_index=0, seed=seed)
        self._plan: Optional[BatchPlan] = None

    # -- checkpointable state ------------------------------------------
    def state(self) -> Dict[str, int]:
        return {"epoch": self._state.epoch,
                "batch_index": self._state.batch_index, "seed": self.seed}

    def restore(self, state: Dict[str, int]) -> None:
        self._state = IteratorState(**state)
        self.seed = state["seed"]
        self._plan = None

    # -- epoch plan ------------------------------------------------------
    def epoch_plan(self, epoch: Optional[int] = None) -> BatchPlan:
        epoch = self._state.epoch if epoch is None else epoch
        rng = np.random.RandomState((self.seed, epoch))
        sls = self.dist.sample(rng, self.samples_per_epoch)
        return plan_epoch(
            sls, self.batch_size, granularity=self.granularity,
            bucketed=self.bucketed,
            sort_first=(self.sort_first_epoch and epoch == 0),
            seed=self.seed + epoch)

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray, int]]:
        while True:
            if self._plan is None:
                self._plan = self.epoch_plan()
            plan = self._plan
            while self._state.batch_index < plan.num_batches:
                i = self._state.batch_index
                sl = int(plan.padded_sls[i])
                rng = np.random.RandomState(
                    (self.seed, self._state.epoch, i))
                bs_local = self.batch_size // self.num_shards
                toks = sample_tokens(rng, (self.batch_size, sl + 1),
                                     self.vocab_size)
                lens = plan.member_sls[i]
                mask = np.arange(sl + 1)[None, :] < lens[:, None] + 1
                toks = np.where(mask, toks, 0)
                labels = np.where(mask[:, 1:], toks[:, 1:], -1)
                lo = self.shard_id * bs_local
                # advance state BEFORE yielding so a checkpoint taken after
                # consuming this batch resumes at the next one
                self._state.batch_index += 1
                yield (toks[lo:lo + bs_local, :-1],
                       labels[lo:lo + bs_local], sl)
            self._state = IteratorState(self._state.epoch + 1, 0, self.seed)
            self._plan = None
