"""Synthetic datasets with realistic sequence-length distributions.

Paper Fig. 7 shows the two characteristic shapes: LibriSpeech (DS2) — a
broad, right-skewed distribution of audio-frame counts; IWSLT (GNMT) — a
decaying distribution of sentence lengths. We model both plus generic
lognormal/uniform samplers, and a Zipf token sampler so embedding-gather
behavior is vocabulary-realistic (paper key obs. 6: keep vocabulary full
size).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

import numpy as np


@dataclass(frozen=True)
class SLDistribution:
    name: str
    sampler: Callable[[np.random.RandomState, int], np.ndarray]
    min_len: int
    max_len: int

    def sample(self, rng: np.random.RandomState, n: int) -> np.ndarray:
        sls = self.sampler(rng, n)
        return np.clip(np.round(sls).astype(np.int64), self.min_len,
                       self.max_len)


def _librispeech(rng: np.random.RandomState, n: int) -> np.ndarray:
    """Audio-frame counts: mixture of utterance lengths, right-skewed with a
    bulk around 12-16 s (paper Fig. 7a shape)."""
    bulk = rng.normal(loc=800, scale=280, size=int(n * 0.8))
    tail = rng.exponential(scale=320, size=n - int(n * 0.8)) + 900
    return np.concatenate([bulk, tail])


def _iwslt(rng: np.random.RandomState, n: int) -> np.ndarray:
    """Sentence lengths (words): decaying lognormal (paper Fig. 7b shape)."""
    return rng.lognormal(mean=3.0, sigma=0.55, size=n)


LIBRISPEECH_LIKE = SLDistribution("librispeech-like", _librispeech, 40, 1700)
IWSLT_LIKE = SLDistribution("iwslt-like", _iwslt, 2, 128)


def lognormal(mean: float, sigma: float, min_len: int,
              max_len: int) -> SLDistribution:
    return SLDistribution(
        f"lognormal({mean},{sigma})",
        lambda rng, n: rng.lognormal(mean=mean, sigma=sigma, size=n),
        min_len, max_len)


def uniform(min_len: int, max_len: int) -> SLDistribution:
    return SLDistribution(
        f"uniform({min_len},{max_len})",
        lambda rng, n: rng.uniform(min_len, max_len, size=n),
        min_len, max_len)


# LM-style pretraining/sft mixtures for the assigned archs: document lengths
# up to the shape's seq_len (used by the Characterizer, DESIGN.md §2)
def lm_documents(max_len: int) -> SLDistribution:
    def sampler(rng: np.random.RandomState, n: int) -> np.ndarray:
        ln = rng.lognormal(mean=np.log(max_len * 0.18), sigma=0.9, size=n)
        return ln
    return SLDistribution(f"lm-docs(max={max_len})", sampler, 16, max_len)


DISTRIBUTIONS: Dict[str, SLDistribution] = {
    "librispeech": LIBRISPEECH_LIKE,
    "iwslt": IWSLT_LIKE,
}


def sample_tokens(rng: np.random.RandomState, shape, vocab_size: int,
                  zipf_a: float = 1.2) -> np.ndarray:
    """Zipf-ish token ids in [0, vocab)."""
    n = int(np.prod(shape))
    ranks = rng.zipf(zipf_a, size=n).astype(np.int64)
    return (np.minimum(ranks, vocab_size) - 1).reshape(shape)
