"""repro.serve.sched — SL-aware continuous-batching scheduler.

SeqPoint's observation (per-iteration compute is keyed by padded SL)
applied to the serving request lifecycle: log2-SL-bucketed admission
queues (``queue``), pluggable admission policies (``policy``), and a
continuous-batching loop that admits into free decode slots at step
granularity and evicts finished sequences immediately (``loop``). Entry
point: ``ServeEngine.serve(requests, policy=...)``; baseline comparison:
``loop.run_to_completion``.
"""
from repro.serve.sched.loop import (
    ContinuousBatcher,
    ServeStats,
    run_to_completion,
)
from repro.serve.sched.policy import (
    AdmissionPolicy,
    BucketAffinePolicy,
    FifoPolicy,
    SeqPointPolicy,
    cost_from_provider,
)
from repro.serve.sched.queue import AdmissionQueue, Ticket, sl_bucket

__all__ = [
    "AdmissionPolicy", "AdmissionQueue", "BucketAffinePolicy",
    "ContinuousBatcher", "FifoPolicy", "SeqPointPolicy", "ServeStats",
    "Ticket", "cost_from_provider", "run_to_completion", "sl_bucket",
]
