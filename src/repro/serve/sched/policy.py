"""Pluggable admission policies for the continuous-batching scheduler.

A policy answers one question: given the eligible tickets (arrival order)
and ``n_slots`` free slots, which requests enter the batch now? All three
shipped policies are deterministic — same queue state in, same admission
out — which is what the scheduler's replayability contract requires.

* ``FifoPolicy`` — arrival order, SL-blind. The baseline: a 512-SL prompt
  landing next to an 8-SL prompt pads the whole micro-batch to 512.
* ``BucketAffinePolicy`` — anchors on the oldest ticket (no starvation),
  then prefers tickets from the same log2 bucket, then the nearest
  buckets. Minimizes padded width without an explicit cost model.
* ``SeqPointPolicy`` — weighs candidates with a per-SL cost model (e.g.
  ``core.characterize`` provider runtimes): picks the admission set that
  maximizes useful-compute per padded-compute, SeqPoint's per-SL cost
  observation applied to batch formation. Falls back to bucket-affine
  ordering when costs tie.
"""
from __future__ import annotations

from typing import Callable, List, Sequence

from repro.serve.sched.queue import Ticket


class AdmissionPolicy:
    name = "base"

    def select(self, tickets: Sequence[Ticket],
               n_slots: int) -> List[Ticket]:
        raise NotImplementedError

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


class FifoPolicy(AdmissionPolicy):
    """Strict arrival order, blind to SL (the run-to-completion default)."""

    name = "fifo"

    def select(self, tickets: Sequence[Ticket],
               n_slots: int) -> List[Ticket]:
        return list(tickets[:max(0, n_slots)])


class BucketAffinePolicy(AdmissionPolicy):
    """Admit the oldest ticket, then pack its log2 bucket first.

    The oldest eligible request is always admitted — aging beats packing,
    so no bucket can starve another. Remaining slots are filled from the
    anchor's bucket in FIFO order, then from other buckets by increasing
    padded-width distance to the anchor (ties: smaller bucket first, then
    arrival order). Narrower buckets join a wide batch for free; admitting
    a wider ticket raises the batch width, so it comes last.
    """

    name = "bucket_affine"

    def select(self, tickets: Sequence[Ticket],
               n_slots: int) -> List[Ticket]:
        if not tickets or n_slots <= 0:
            return []
        anchor = min(tickets, key=lambda t: t.seq)
        rest = [t for t in tickets if t is not anchor]
        rest.sort(key=lambda t: (abs(t.padded - anchor.padded),
                                 t.padded, t.seq))
        return [anchor] + rest[:n_slots - 1]


class SeqPointPolicy(AdmissionPolicy):
    """Cost-model-weighted admission (SeqPoint applied to batch formation).

    ``cost(sl)`` gives the per-iteration compute of a padded-SL-``sl``
    batch — a ``core.characterize`` provider's per-SL runtime, an SLTable
    lookup, or any monotone proxy (``lambda sl: sl`` reproduces grid
    area). For every candidate batch width ``W`` (the padded width of some
    eligible ticket at least as wide as the oldest one), the policy packs
    the oldest ticket plus the highest-cost tickets with ``padded <= W``
    (ties broken by arrival) and scores the set by

        sum(cost(padded_i)) / (n_slots * cost(W))

    — the useful fraction of the compute the padded batch will burn.
    Packing cost-descending matters: filling a wide wave with whatever
    arrived first dilutes it with cheap narrow tickets, while grouping
    the wide ones lets the narrow ones ride a later, narrower wave. The
    best-scoring width wins; the oldest eligible ticket is always in the
    set, so aging is preserved.
    """

    name = "seqpoint"

    def __init__(self, cost: Callable[[int], float]):
        self.cost = cost

    def __repr__(self) -> str:
        return "SeqPointPolicy(cost=...)"

    def select(self, tickets: Sequence[Ticket],
               n_slots: int) -> List[Ticket]:
        if not tickets or n_slots <= 0:
            return []
        anchor = min(tickets, key=lambda t: t.seq)
        widths = sorted({t.padded for t in tickets if t.padded >=
                         anchor.padded})
        best, best_score = None, -1.0
        for w in widths:
            pool = sorted((t for t in tickets
                           if t.padded <= w and t is not anchor),
                          key=lambda t: (-float(self.cost(t.padded)),
                                         t.seq))
            cands = [anchor] + pool[:n_slots - 1]
            denom = n_slots * max(float(self.cost(w)), 1e-12)
            score = sum(float(self.cost(t.padded)) for t in cands) / denom
            if score > best_score + 1e-12:
                best, best_score = cands, score
        return best or [anchor]


def cost_from_provider(provider) -> Callable[[int], float]:
    """Adapt a ``core.characterize`` provider (``profile(sl).runtime``)
    into a ``SeqPointPolicy`` cost model."""
    def cost(sl: int) -> float:
        return float(provider.profile(int(sl)).runtime)
    return cost
