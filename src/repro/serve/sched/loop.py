"""Continuous-batching engine loop: slot admission at decode-step
granularity.

The run-to-completion ``ServeEngine.run_batch`` admits a batch, prefills
it, decodes every request to its last token, and only then looks at the
queue again — short sequences pay for the longest one twice (padding at
prefill, idle slots at decode). This loop keeps the engine's ``batch_size``
decode slots independently occupied instead:

* a finished sequence is evicted the moment its last token is emitted and
  its slot is free for the very next admission check;
* new requests are admitted *mid-stream* between decode steps: their
  prompt is prefilled right-aligned at the shared write position ``pos``
  (absolute rope offset ``pos - W``) and the resulting KV rows are spliced
  into the live cache, so active slots never stop decoding;
* admission is SL-aware: the queue is log2-bucketed (same geometry as the
  ``repro.obs`` histograms) and a pluggable policy picks which buckets to
  pack together (``policy.py``), keeping the padded prefill width honest.

Shared-position invariant: all slots advance one shared cache position per
decode step, so a request is only splice-admissible once its padded width
fits under ``pos`` (``padded <= pos``) and its decode tail fits under
``max_len``. When the engine fully drains, the position resets with a
fresh prefill wave. Cache rows of an admitted slot below its prompt are
zeroed; the attention mask still ranges over ``[0, pos]``, so those zero
keys act as a shared null attention sink — the documented semantic delta
vs run-to-completion padding (which attends pad-token KV instead). The
scheduler's determinism, accounting, and cost behavior do not depend on
it.

Resilience composition: injected ``decode`` faults fire inside the loop's
decode step and are retried with the engine's backoff policy; ``peer_slow``
fires per admission prefill (the micro-batch), and with ``n_replicas > 1``
a prefill running ``hedge_factor``× past its per-width median is hedged
onto the next-healthiest replica — first (virtual) finisher wins, the
loser takes a strike. Per-request deadlines (``engine.deadline_s``,
clocked from admission) curtail mid-decode with ``curtailed=True``, and a
bounded queue (``max_queue``) sheds instead of growing without limit.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.resilience import faults
from repro.resilience.guards import StepTimeWatchdog
from repro.resilience.recovery import retry_with_backoff
from repro.serve.sched.policy import AdmissionPolicy, BucketAffinePolicy
from repro.serve.sched.queue import AdmissionQueue, Ticket


@dataclass
class ServeStats:
    """Deterministic accounting of one scheduler (or baseline) run.

    Grid cells are the padded compute proxy SeqPoint's SL observation
    rests on: every prefill burns ``batch_size x width`` cells and every
    decode step ``batch_size`` cells, useful or not. ``padding_waste`` and
    ``grid_throughput`` are therefore clock-free and bit-stable across
    runs, while ``throughput`` uses the (possibly fake) wall clock.
    """

    n_requests: int = 0
    n_finished: int = 0
    n_curtailed: int = 0
    n_shed: int = 0
    tokens_out: int = 0
    prefills: int = 0
    decode_steps: int = 0
    prefill_cells: int = 0
    prefill_useful: int = 0
    decode_cells: int = 0
    decode_useful: int = 0
    wall_s: float = 0.0
    admission_order: List[int] = field(default_factory=list)

    @property
    def total_cells(self) -> int:
        return self.prefill_cells + self.decode_cells

    @property
    def total_useful(self) -> int:
        return self.prefill_useful + self.decode_useful

    @property
    def padding_waste(self) -> float:
        return 1.0 - self.total_useful / self.total_cells \
            if self.total_cells else 0.0

    @property
    def grid_throughput(self) -> float:
        """Useful tokens emitted per padded grid cell (clock-free)."""
        return self.tokens_out / self.total_cells if self.total_cells \
            else 0.0

    @property
    def throughput(self) -> float:
        return self.tokens_out / self.wall_s if self.wall_s > 0 else 0.0

    def summary(self) -> dict:
        return {
            "n_requests": self.n_requests, "n_finished": self.n_finished,
            "n_curtailed": self.n_curtailed, "n_shed": self.n_shed,
            "tokens_out": self.tokens_out, "prefills": self.prefills,
            "decode_steps": self.decode_steps,
            "padding_waste": self.padding_waste,
            "grid_throughput": self.grid_throughput,
            "throughput": self.throughput, "wall_s": self.wall_s,
        }


@dataclass(eq=False)
class _Slot:
    """One occupied decode slot: the admitted ticket plus its per-slot
    KV/state occupancy window and token progress."""

    ticket: Ticket
    t_admit: float
    start: int               # first cache position of its prompt
    width: int               # padded prompt width actually prefilled
    m_eff: int               # effective token budget (capacity-clamped)
    emitted: int = 0
    ttft_s: float = float("nan")   # submit -> first token

    @property
    def done(self) -> bool:
        return self.emitted >= self.m_eff


class ContinuousBatcher:
    """The request-lifecycle scheduler around one ``ServeEngine``."""

    def __init__(self, engine, *, policy: Optional[AdmissionPolicy] = None,
                 max_queue: Optional[int] = None):
        self.engine = engine
        self.policy = policy or BucketAffinePolicy()
        self.queue = AdmissionQueue(engine.max_len, timer=engine._now,
                                    max_depth=max_queue)
        self.slots: List[Optional[_Slot]] = [None] * engine.batch_size
        self.pos = 0                     # shared cache write position
        self.cache = None
        self.token = jnp.zeros((engine.batch_size, 1), jnp.int32)
        self.stats = ServeStats()
        # per-width prefill latency baseline for micro-batch hedging
        self.prefill_watchdog = StepTimeWatchdog(
            factor=engine.hedge_factor)

    # -- queue side -----------------------------------------------------
    def submit(self, req) -> Optional[Ticket]:
        self.stats.n_requests += 1
        t = self.queue.submit(req)
        if t is None:
            self.stats.n_shed += 1
        return t

    # -- admission ------------------------------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _active(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def _admit(self, fresh: bool) -> int:
        """Admit eligible requests into free slots; returns #admitted.

        ``fresh``: the engine is drained — reset the shared position and
        the cache, and admit without the position constraint.
        """
        eng = self.engine
        free = self._free_slots()
        if not free or not self.queue.depth():
            return 0
        if fresh:
            eligible = self.queue.eligible()
        else:
            eligible = self.queue.eligible(
                pos=self.pos, budget=eng.max_len - self.pos)
        picked = self.policy.select(eligible, len(free))
        if not picked:
            return 0
        self.queue.take(picked)
        width = max(t.padded for t in picked)
        if fresh:
            self.pos = width
            self.cache = None
            self.token = jnp.zeros((eng.batch_size, 1), jnp.int32)
            for i in range(eng.batch_size):
                self.slots[i] = None
        start = self.pos - width
        rows = free[:len(picked)]

        toks = np.zeros((eng.batch_size, width), np.int32)
        useful = 0
        for row, t in zip(rows, picked):
            prompt = np.asarray(t.req.prompt, np.int32)[-width:]
            if len(prompt):
                toks[row, -len(prompt):] = prompt
            useful += min(t.sl, width)
        self.stats.prefills += 1
        self.stats.prefill_cells += eng.batch_size * width
        self.stats.prefill_useful += useful
        obs.metrics.counter("serve_sched_prefills_total").inc()
        obs.metrics.histogram("serve_sched_prefill_fill",
                              sl=width).observe(len(picked) /
                                                eng.batch_size)

        logits, caches, latency = self._prefill_hedged(toks, start, width,
                                                       len(picked))
        self.prefill_watchdog.observe(width, latency)
        self._splice(caches, rows, start, width)
        first = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                           axis=-1).astype(jnp.int32)
        tok = np.asarray(self.token).copy()
        now = eng._now()
        # positions [pos, max_len) remain for decode: m_eff - 1 decode
        # writes land at pos .. pos + m_eff - 2, so the tail always fits
        budget = eng.max_len - self.pos + 1
        for row, t in zip(rows, picked):
            tok[row, 0] = int(first[row])
            m_eff = max(0, min(t.req.max_new_tokens, budget))
            slot = _Slot(ticket=t, t_admit=now, start=start, width=width,
                         m_eff=m_eff)
            self.slots[row] = slot
            self.stats.admission_order.append(t.seq)
            obs.metrics.counter("serve_sched_admitted_total",
                                bucket=t.padded).inc()
            if m_eff > 0:                 # first token comes from prefill
                t.req.output.append(int(first[row]))
                slot.emitted = 1
                slot.ttft_s = now - t.t_submit
                self.stats.tokens_out += 1
                obs.metrics.histogram("serve_sched_ttft_s", sl=t.padded
                                      ).observe(slot.ttft_s)
            if slot.done:
                self._evict(row, curtailed=m_eff < t.req.max_new_tokens)
        self.token = jnp.asarray(tok)
        self._set_occupancy()
        return len(picked)

    def _prefill_hedged(self, toks: np.ndarray, pos0: int, width: int,
                        n_adm: int):
        """One admission prefill (a micro-batch), hedged across replicas.

        A ``peer_slow`` fault at the execution index adds a virtual delay
        to this prefill only; if the virtual latency runs past
        ``hedge_factor`` x the per-width median baseline and another
        replica is available, the prefill is re-issued there and the
        faster (virtual) execution's latency is the one committed.
        """
        eng = self.engine

        def one_exec():
            idx = eng._exec_index
            eng._exec_index += 1
            spec = faults.check("peer_slow", idx)
            penalty = float(spec.delay) if spec is not None else 0.0
            t0 = eng._now()
            with obs.span("serve/sched/prefill", sl=width, batch=n_adm):
                logits, caches = eng._prefill(
                    eng.params, {"tokens": jnp.asarray(toks)},
                    jnp.asarray(pos0, jnp.int32))
                jax.block_until_ready(logits)
            return logits, caches, eng._now() - t0 + penalty

        primary = eng.replicas.pick_primary()
        logits, caches, latency = one_exec()
        baseline = self.prefill_watchdog.baseline(width)
        cutoff = eng.hedge_factor * baseline \
            if baseline is not None and eng.replicas.n > 1 else None
        if cutoff is not None and latency > cutoff:
            hedge_replica = eng.replicas.pick_hedge(exclude=primary)
            obs.metrics.counter("serve_hedges_total").inc()
            obs.event("hedge_fired", sl=width, primary=primary,
                      hedge_replica=hedge_replica, at_s=latency,
                      baseline_s=baseline, factor=eng.hedge_factor,
                      micro_batch=True)
            h_logits, h_caches, h_latency = one_exec()
            # the hedge starts at the detection instant — the earliest the
            # watchdog could have fired is the cutoff itself
            h_total = cutoff + h_latency
            if h_total < latency:
                eng.replicas.mark_slow(primary)
                eng.replicas.mark_ok(hedge_replica)
                obs.metrics.counter("serve_hedge_wins_total").inc()
                obs.event("hedge_won", sl=width, winner=hedge_replica,
                          latency_s=h_total, primary_latency_s=latency)
                obs.event("hedge_cancelled", sl=width, loser=primary,
                          wasted_tokens=0)
                return h_logits, h_caches, h_latency
            eng.replicas.mark_ok(primary)
            obs.event("hedge_cancelled", sl=width, loser=hedge_replica,
                      wasted_tokens=0)
        else:
            eng.replicas.mark_ok(primary)
        return logits, caches, latency

    def _splice(self, caches, rows: List[int], start: int,
                width: int) -> None:
        """Write the prefill's KV rows into the live cache.

        Admitted rows are zeroed first (dropping the evicted occupant's
        stale KV), then the prompt window [start, start+width) is updated.
        Leaves whose axis 2 is the ``max_len`` sequence axis take the
        windowed splice; same-shaped state leaves (recurrent blocks) are
        replaced row-wise; anything else is left alone.
        """
        eng = self.engine
        if self.cache is None:
            self.cache = eng.model.init_cache(eng.batch_size, eng.max_len)
        mask = np.zeros((eng.batch_size,), bool)
        mask[rows] = True
        mask = jnp.asarray(mask)

        def spl(dst, src):
            m = mask.reshape((1, -1) + (1,) * (dst.ndim - 2)) \
                if dst.ndim >= 2 else mask
            if dst.ndim >= 3 and dst.shape[:2] == src.shape[:2] \
                    and dst.shape[3:] == src.shape[3:] \
                    and dst.shape[2] == eng.max_len \
                    and src.shape[2] == width:
                upd = jax.lax.dynamic_update_slice_in_dim(
                    jnp.where(m, 0.0, dst).astype(dst.dtype),
                    src.astype(dst.dtype), start, axis=2)
                return jnp.where(m, upd, dst)
            if dst.shape == src.shape:
                return jnp.where(m, src.astype(dst.dtype), dst)
            return dst

        self.cache = jax.tree.map(spl, self.cache, caches)

    # -- decode / eviction ----------------------------------------------
    def _decode_once(self) -> None:
        eng = self.engine
        active = self._active()
        with obs.span("serve/sched/decode_token", pos=self.pos,
                      active=len(active)):
            def decode_once():
                faults.fire("decode", eng._decode_calls)
                return eng._decode(eng.params, self.cache, self.token,
                                   jnp.asarray(self.pos, jnp.int32))
            logits, self.cache = retry_with_backoff(
                decode_once, retries=eng.policy.max_retries,
                base_delay=eng.policy.backoff_base_s,
                factor=eng.policy.backoff_factor,
                max_delay_s=eng.policy.max_delay_s,
                jitter_frac=eng.policy.jitter_frac,
                jitter_seed=eng.policy.jitter_seed,
                label="serve_sched_decode")
            eng._decode_calls += 1
            self.token = jnp.argmax(logits, axis=-1
                                    ).astype(jnp.int32)[:, None]
            jax.block_until_ready(self.token)
        self.pos += 1
        self.stats.decode_steps += 1
        self.stats.decode_cells += eng.batch_size
        obs.metrics.counter("serve_sched_decode_steps_total").inc()

        tok = np.asarray(self.token)
        for i in active:
            slot = self.slots[i]
            slot.ticket.req.output.append(int(tok[i, 0]))
            slot.emitted += 1
            self.stats.tokens_out += 1
            self.stats.decode_useful += 1
            if slot.done:
                self._evict(i, curtailed=slot.m_eff <
                            slot.ticket.req.max_new_tokens)

    def _evict(self, row: int, *, curtailed: bool) -> None:
        """Free a slot the moment its sequence is finished (or cut)."""
        eng = self.engine
        slot = self.slots[row]
        self.slots[row] = None
        t = slot.ticket
        now = eng._now()
        t.req.curtailed = bool(curtailed)
        latency = now - slot.t_admit
        self.stats.n_finished += 1
        self.stats.n_curtailed += int(curtailed)
        mreg = obs.metrics
        mreg.counter("serve_sched_evicted_total").inc()
        if curtailed:
            mreg.counter("serve_sched_curtailed_total").inc()
        mreg.histogram("serve_sched_request_latency_s",
                       sl=t.padded).observe(latency)
        # one EpochLog record per request, keyed by its padded SL: the
        # serving trace stays SeqPoint-summarizable under the scheduler
        eng.log.append(t.padded, latency,
                       tokens_out=float(slot.emitted),
                       ttft_s=float(slot.ttft_s),
                       queue_wait_s=slot.t_admit - t.t_submit,
                       curtailed=float(curtailed), sl_raw=float(t.sl))
        self._set_occupancy()

    def _set_occupancy(self) -> None:
        obs.metrics.gauge("serve_sched_slot_occupancy").set(
            len(self._active()) / self.engine.batch_size)

    def _curtail_deadline(self) -> None:
        eng = self.engine
        if eng.deadline_s is None:
            return
        now = eng._now()
        for i in self._active():
            slot = self.slots[i]
            if now - slot.t_admit > eng.deadline_s:
                obs.metrics.counter("serve_deadline_exceeded_total").inc()
                obs.event("serve_deadline", sl=slot.ticket.padded,
                          deadline_s=eng.deadline_s,
                          curtailed_tokens=slot.m_eff - slot.emitted)
                self._evict(i, curtailed=True)

    # -- the loop -------------------------------------------------------
    def run(self) -> ServeStats:
        """Drain the queue: admit / decode / evict until nothing is left.

        Every tick: curtail slots past their deadline, admit eligible
        requests into free slots (a full drain resets the position with a
        fresh wave), then run one shared decode step. Wall time and the
        running padding-waste gauge are committed into ``stats``.
        """
        eng = self.engine
        t0 = eng._now()
        while True:
            self._curtail_deadline()
            if not self._active():
                if not self.queue.depth():
                    break
                if self._admit(fresh=True) == 0:
                    raise RuntimeError(
                        f"admission policy {self.policy!r} admitted "
                        "nothing on a drained engine (would spin)")
                continue
            if self._free_slots() and self.queue.depth():
                self._admit(fresh=False)
            if not self._active():
                continue
            self._decode_once()
            obs.metrics.gauge("serve_sched_padding_waste").set(
                self.stats.padding_waste)
        self.stats.wall_s = eng._now() - t0
        obs.metrics.gauge("serve_sched_padding_waste").set(
            self.stats.padding_waste)
        obs.event("serve_sched_drain", **self.stats.summary())
        return self.stats


# --------------------------------------------------------------------------
# run-to-completion baseline with the same grid accounting


def run_to_completion(engine, requests) -> ServeStats:
    """Serve ``requests`` with plain FIFO ``run_batch`` chunks and account
    the same padded-grid cells the scheduler reports, so the two paths are
    directly comparable (the CI smoke job and the acceptance test diff
    their ``padding_waste`` / ``grid_throughput``)."""
    stats = ServeStats(n_requests=len(requests))
    t0 = engine._now()
    for c0 in range(0, len(requests), engine.batch_size):
        chunk = requests[c0:c0 + engine.batch_size]
        engine.run_batch(chunk)
        rec = engine.log.iterations[-1]
        width = int(rec.seq_len)
        calls = int(rec.stats["decode_steps"])
        stats.prefills += 1
        stats.prefill_cells += engine.batch_size * width
        stats.prefill_useful += sum(min(len(r.prompt), width)
                                    for r in chunk)
        stats.decode_steps += calls
        stats.decode_cells += calls * engine.batch_size
        stats.decode_useful += sum(max(0, len(r.output) - 1)
                                   for r in chunk)
        stats.tokens_out += int(rec.stats["tokens_out"])
        stats.n_finished += len(chunk)
        stats.n_curtailed += int(rec.stats.get("curtailed", 0.0))
        stats.admission_order.extend(range(c0, c0 + len(chunk)))
    stats.wall_s = engine._now() - t0
    return stats
