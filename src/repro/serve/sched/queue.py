"""SL-bucketed admission queues (SeqPoint's binning applied to serving).

Requests are queued by the log2 bucket of their prompt SL — the same
``bucket_bound`` geometry ``repro.obs`` uses for its histograms, so queue
metrics, step-time histograms, and admission decisions all speak the same
bucket language. Within a bucket the order is strict FIFO by a global
arrival sequence number, which is what makes scheduler runs replayable:
admission order is a pure function of (request set, policy, fault plan).

A ``Ticket`` is the queue's view of a request: arrival seq, submit time,
raw prompt SL, and the padded width the scheduler would prefill it at
(its bucket bound, capped at the engine's ``max_len``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import TYPE_CHECKING, Callable, Dict, List, Optional

from repro import obs
from repro.obs.metrics import bucket_bound

if TYPE_CHECKING:                                    # avoid an import cycle
    from repro.serve.engine import Request


def sl_bucket(sl: int) -> int:
    """Log2 bucket of a prompt SL: smallest power of two >= sl (min 1)."""
    return int(bucket_bound(max(1, int(sl))))


@dataclass(eq=False)                     # identity equality: Request holds
class Ticket:                            # arrays, field-wise == is ambiguous
    req: "Request"
    seq: int                 # global arrival order (admission tiebreaker)
    t_submit: float
    sl: int                  # raw prompt length
    padded: int              # log2-bucket width the prefill would run at

    @property
    def bucket(self) -> int:
        return self.padded


class AdmissionQueue:
    """Per-bucket FIFO queues with a global arrival order.

    ``submit`` assigns the arrival seq and updates the per-bucket
    ``serve_sched_queue_depth`` gauge; ``take`` removes admitted tickets.
    ``eligible`` applies the continuous-batching admission constraints
    (padded width must fit under the current write position, the remaining
    decode budget must fit under ``max_len``) without consuming anything.
    """

    def __init__(self, max_len: int = 512, *,
                 timer: Callable[[], float] = None,
                 max_depth: Optional[int] = None):
        import time
        self.max_len = int(max_len)
        self.max_depth = max_depth
        self._now = timer or time.perf_counter
        self._buckets: Dict[int, List[Ticket]] = {}
        self._seq = 0
        self.submitted = 0
        self.shed = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> Optional[Ticket]:
        """Queue a request; returns its Ticket, or None if shed on a full
        queue (``req.shed`` is set so the caller can requeue later)."""
        if self.max_depth is not None and self.depth() >= self.max_depth:
            req.shed = True
            self.shed += 1
            obs.metrics.counter("serve_shed_total").inc()
            obs.event("serve_shed", count=1, queued=self.depth())
            return None
        req.shed = False
        padded = min(self.max_len, sl_bucket(len(req.prompt)))
        t = Ticket(req=req, seq=self._seq, t_submit=self._now(),
                   sl=int(len(req.prompt)), padded=padded)
        self._seq += 1
        self.submitted += 1
        self._buckets.setdefault(padded, []).append(t)
        obs.metrics.gauge("serve_sched_queue_depth",
                          bucket=padded).set(len(self._buckets[padded]))
        return t

    def take(self, tickets: List[Ticket]) -> None:
        for t in tickets:
            self._buckets[t.padded].remove(t)
            obs.metrics.gauge("serve_sched_queue_depth", bucket=t.padded
                              ).set(len(self._buckets[t.padded]))

    # ------------------------------------------------------------------
    def depth(self, bucket: Optional[int] = None) -> int:
        if bucket is not None:
            return len(self._buckets.get(bucket, []))
        return sum(len(q) for q in self._buckets.values())

    def buckets(self) -> List[int]:
        return sorted(b for b, q in self._buckets.items() if q)

    def pending(self) -> List[Ticket]:
        """All queued tickets in arrival order."""
        out = [t for q in self._buckets.values() for t in q]
        out.sort(key=lambda t: t.seq)
        return out

    def oldest(self) -> Optional[Ticket]:
        p = self.pending()
        return p[0] if p else None

    def eligible(self, *, pos: Optional[int] = None,
                 budget: Optional[int] = None) -> List[Ticket]:
        """Tickets admissible right now, in arrival order.

        ``pos``: current shared write position — a ticket's padded prompt
        must fit in [pos - padded, pos), so ``padded <= pos``. ``budget``:
        remaining decode positions before ``max_len`` — the request's
        decode tail (``max_new_tokens - 1`` steps past admission) must fit.
        Either constraint may be None (unconstrained, e.g. a fresh wave).
        """
        out = []
        for t in self.pending():
            if pos is not None and t.padded > pos:
                continue
            if budget is not None and max(0, t.req.max_new_tokens - 1) > \
                    budget:
                continue
            out.append(t)
        return out
