"""repro.serve — the serving side of the stack.

``engine`` holds the batched prefill+decode executor (``ServeEngine``);
``sched`` holds the SL-aware request-lifecycle scheduler (admission queues,
pluggable policies, and the continuous-batching loop). See
``src/repro/serve/README.md`` for the architecture.
"""
from repro.serve.engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
