"""Minimal batched serving engine: continuous prefill+decode over a request
queue with a fixed-shape KV cache (the decode_32k dry-run cell's runtime
counterpart).

SeqPoint's insight applies at serving too (paper §VII-E): per-request
prefill cost is keyed by prompt SL, so the engine logs (SL, prefill
latency) — with decode time, decode-call count, and emitted-token stats on
the same record — and ``seqpoints()`` summarizes a serving trace the same
way training epochs are summarized.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.profile import EpochLog
from repro.core.seqpoint import SeqPointSet, select_seqpoints
from repro.models.model_zoo import Model
from repro.resilience import faults
from repro.resilience.recovery import RecoveryPolicy, retry_with_backoff


@dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    output: List[int] = field(default_factory=list)
    shed: bool = False            # dropped on overload, never ran


class ServeEngine:
    def __init__(self, model: Model, params, *, batch_size: int = 4,
                 max_len: int = 512, sl_granularity: int = 32,
                 deadline_s: Optional[float] = None,
                 policy: Optional[RecoveryPolicy] = None):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.gran = sl_granularity
        self.deadline_s = deadline_s
        self.policy = policy or RecoveryPolicy()
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=1)
        self._decode_calls = 0
        self.log = EpochLog(meta={"kind": "serve"})

    def _pad(self, sl: int) -> int:
        return min(self.max_len, -(-sl // self.gran) * self.gran)

    def run_batch(self, requests: List[Request]) -> List[Request]:
        """Prefill a batch of same-padded-SL requests, then decode.

        Pads the batch with dummy requests on a local copy only; the
        caller's list is never mutated and only the real requests are
        returned. Prefill's last-position logits supply the first generated
        token, so ``n_steps`` useful tokens cost ``n_steps - 1`` decode
        calls.

        Overload sheds instead of crashing: requests beyond ``batch_size``
        come back with ``shed=True`` and empty output for the caller to
        requeue. With ``deadline_s`` set, decode stops once the batch has
        used its budget (prefill included) and the remaining tokens are
        curtailed — latency SLO over completion. Transient decode faults
        are retried with backoff (the injected ones fire before the jitted
        call, so no cache state is lost).
        """
        mreg = obs.metrics
        mreg.gauge("serve_queue_depth").set(len(requests))
        admitted = requests[:self.batch_size]
        for r in requests[self.batch_size:]:              # shed-on-overload
            r.shed = True
        n_shed = len(requests) - len(admitted)
        if n_shed:
            mreg.counter("serve_shed_total").inc(n_shed)
            obs.event("serve_shed", count=n_shed, admitted=len(admitted))
        mreg.gauge("serve_batch_fill").set(len(admitted) / self.batch_size)
        batch_t0 = time.perf_counter()                    # deadline clock
        batch = list(admitted)
        while len(batch) < self.batch_size:               # pad batch
            batch.append(Request(prompt=np.zeros(4, np.int32),
                                 max_new_tokens=0))
        sl = self._pad(max(len(r.prompt) for r in batch))
        toks = np.zeros((self.batch_size, sl), np.int32)
        real_tokens = 0
        for i, r in enumerate(batch):
            prompt = r.prompt[-sl:]       # keep the most recent sl tokens
            if len(prompt):
                toks[i, -len(prompt):] = prompt
            if i < len(admitted):
                real_tokens += len(prompt)
        # fraction of the (batch, sl) prefill grid that is dummy/pad work
        waste = 1.0 - real_tokens / float(self.batch_size * sl)
        mreg.gauge("serve_padding_waste").set(waste)
        mreg.histogram("serve_padding_waste_frac", sl=sl).observe(waste)
        t0 = time.perf_counter()
        with obs.span("serve/prefill", sl=sl, batch=len(admitted)):
            logits, caches = self._prefill(self.params,
                                           {"tokens": jnp.asarray(toks)})
            jax.block_until_ready(logits)
        prefill_dt = time.perf_counter() - t0
        mreg.histogram("serve_prefill_s", sl=sl).observe(prefill_dt)

        # decode greedily; caches from prefill hold exactly sl entries, so
        # rebuild into the fixed-size serving cache
        full = self.model.init_cache(self.batch_size, self.max_len)
        full = jax.tree.map(
            lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), 0, axis=2)
            if dst.ndim >= 3 and dst.shape[:2] == src.shape[:2]
            and dst.shape[3:] == src.shape[3:] else src.astype(dst.dtype),
            full, caches)
        token = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                           axis=-1).astype(jnp.int32)[:, None]
        n_steps = max((r.max_new_tokens for r in batch), default=0)
        dec_t0 = time.perf_counter()
        emitted = 0                       # tokens delivered to real requests
        decode_calls = 0
        for step in range(n_steps):
            for i, r in enumerate(batch):
                if step < r.max_new_tokens:
                    r.output.append(int(token[i, 0]))
                    if i < len(admitted):
                        emitted += 1
            if step + 1 >= n_steps:       # final token came from the last
                break                     # decode (or prefill) — done
            if self.deadline_s is not None and \
                    time.perf_counter() - batch_t0 > self.deadline_s:
                curtailed = sum(max(0, r.max_new_tokens - len(r.output))
                                for r in admitted)
                mreg.counter("serve_deadline_exceeded_total").inc()
                obs.event("serve_deadline", sl=sl,
                          deadline_s=self.deadline_s,
                          curtailed_tokens=curtailed)
                break
            t1 = time.perf_counter()
            with obs.span("serve/decode_token", pos=sl + step):
                def decode_once():
                    faults.fire("decode", self._decode_calls)
                    return self._decode(self.params, full, token,
                                        jnp.asarray(sl + step, jnp.int32))
                logits, full = retry_with_backoff(
                    decode_once, retries=self.policy.max_retries,
                    base_delay=self.policy.backoff_base_s,
                    factor=self.policy.backoff_factor, label="serve_decode")
                self._decode_calls += 1
                decode_calls += 1
                token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
                jax.block_until_ready(token)
            mreg.histogram("serve_decode_token_s", sl=sl).observe(
                time.perf_counter() - t1)
        decode_dt = time.perf_counter() - dec_t0 if n_steps else 0.0
        # tokens_out counts tokens actually emitted to real requests — not
        # requested tokens summed over the padded batch — so serve
        # throughput metrics stay honest under shedding and deadlines
        self.log.append(sl, prefill_dt, decode_s=decode_dt,
                        decode_steps=float(decode_calls),
                        tokens_out=float(emitted))
        return requests

    def seqpoints(self, **kw) -> SeqPointSet:
        return select_seqpoints(self.log, **kw)
