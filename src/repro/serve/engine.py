"""Minimal batched serving engine: continuous prefill+decode over a request
queue with a fixed-shape KV cache (the decode_32k dry-run cell's runtime
counterpart).

SeqPoint's insight applies at serving too (paper §VII-E): per-request
prefill cost is keyed by prompt SL, so the engine logs (SL, prefill
latency) — with decode time, decode-call count, emitted-token and batch
latency stats on the same record — and ``seqpoints()`` summarizes a serving
trace the same way training epochs are summarized.

Request hedging (tail-latency defense): with ``n_replicas > 1`` the engine
tracks a per-SL running median of past batch latencies
(``StepTimeWatchdog``); when an in-flight batch runs ``hedge_factor``× past
that baseline — detected between decode steps — it is speculatively
re-issued on the next-healthiest simulated replica. First (virtual)
finisher wins; the loser's tokens are discarded, never reaching the caller
or the ``tokens_out`` counter, and the slow replica takes a health strike.
Slowness is injected via the ``peer_slow`` fault point as a *virtual*
per-decode-call penalty keyed by a per-execution index, so the hedge
re-execution (a different index) never inherits the primary's injected
delay and chaos replays stay deterministic.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.profile import EpochLog
from repro.core.seqpoint import SeqPointSet, select_seqpoints
from repro.models.model_zoo import Model
from repro.resilience import faults
from repro.resilience.elastic import ReplicaSet
from repro.resilience.guards import StepTimeWatchdog
from repro.resilience.recovery import RecoveryPolicy, retry_with_backoff


@dataclass
class Request:
    prompt: np.ndarray            # (S,) int32
    max_new_tokens: int = 16
    output: List[int] = field(default_factory=list)
    shed: bool = False            # dropped on overload, never ran
    curtailed: bool = False       # deadline hit mid-decode: partial output


class ServeEngine:
    def __init__(self, model: Model, params, *, batch_size: int = 4,
                 max_len: int = 512, sl_granularity: int = 32,
                 deadline_s: Optional[float] = None,
                 n_replicas: int = 1, hedge_factor: float = 3.0,
                 policy: Optional[RecoveryPolicy] = None,
                 timer: Optional[Callable[[], float]] = None):
        self.model = model
        self.params = params
        self.batch_size = batch_size
        self.max_len = max_len
        self.gran = sl_granularity
        self.deadline_s = deadline_s
        self.hedge_factor = hedge_factor
        self.policy = policy or RecoveryPolicy()
        self.replicas = ReplicaSet(n_replicas)
        # injectable clock: tests pass a FakeClock so every latency, TTFT,
        # and deadline decision is bit-identical across runs
        self._now = timer or time.perf_counter
        # per-SL running median of past batch latencies: the hedge baseline
        self.latency_watchdog = StepTimeWatchdog(factor=hedge_factor)
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=1)
        self._decode_calls = 0
        self._exec_index = 0          # one per batch execution (hedges too)
        self.log = EpochLog(meta={"kind": "serve"})

    def _pad(self, sl: int) -> int:
        return min(self.max_len, -(-sl // self.gran) * self.gran)

    # ------------------------------------------------------------------
    def _execute(self, batch: List[Request], n_admitted: int,
                 toks: np.ndarray, sl: int, batch_t0: float,
                 hedge_cutoff_s: Optional[float]) -> Dict:
        """Run one prefill+decode execution of the batch on one replica.

        Never mutates the ``Request`` objects: generated tokens go into
        local per-row lists and the caller commits only the winning
        execution's outputs. Returns prefill/decode timings, the emitted
        count, the *virtual* batch latency (real elapsed plus any injected
        ``peer_slow`` per-decode-call penalty), and ``hedge_at`` — the
        virtual elapsed time at which the batch crossed ``hedge_cutoff_s``
        (None when it never did or no cutoff was armed).

        The real deadline clock (``batch_t0``) is shared across hedged
        executions: a hedge spends the same SLO budget the primary already
        burned. Injected slowness is virtual and does not consume it.
        """
        mreg = obs.metrics
        exec_index = self._exec_index
        self._exec_index += 1
        # a peer_slow spec firing at this execution degrades every decode
        # call of this execution (slow link), consuming the spec's budget so
        # a hedge re-execution at the next index runs at full speed
        spec = faults.check("peer_slow", exec_index)
        penalty_per_call = float(spec.delay) if spec is not None else 0.0
        penalty = 0.0
        hedge_at: Optional[float] = None
        deadline_hit = False
        exec_t0 = self._now()
        with obs.span("serve/prefill", sl=sl, batch=n_admitted):
            logits, caches = self._prefill(self.params,
                                           {"tokens": jnp.asarray(toks)})
            jax.block_until_ready(logits)
        prefill_dt = self._now() - exec_t0
        mreg.histogram("serve_prefill_s", sl=sl).observe(prefill_dt)

        # decode greedily; caches from prefill hold exactly sl entries, so
        # rebuild into the fixed-size serving cache
        full = self.model.init_cache(self.batch_size, self.max_len)
        full = jax.tree.map(
            lambda dst, src: jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), 0, axis=2)
            if dst.ndim >= 3 and dst.shape[:2] == src.shape[:2]
            and dst.shape[3:] == src.shape[3:] else src.astype(dst.dtype),
            full, caches)
        token = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                           axis=-1).astype(jnp.int32)[:, None]
        n_steps = max((r.max_new_tokens for r in batch), default=0)
        dec_t0 = self._now()
        outputs: List[List[int]] = [[] for _ in batch]
        emitted = 0                       # tokens bound for real requests
        decode_calls = 0
        for step in range(n_steps):
            for i, r in enumerate(batch):
                if step < r.max_new_tokens:
                    outputs[i].append(int(token[i, 0]))
                    if i < n_admitted:
                        emitted += 1
            if step + 1 >= n_steps:       # final token came from the last
                break                     # decode (or prefill) — done
            if self.deadline_s is not None and \
                    self._now() - batch_t0 > self.deadline_s:
                curtailed = sum(
                    max(0, r.max_new_tokens - len(outputs[i]))
                    for i, r in enumerate(batch) if i < n_admitted)
                deadline_hit = True
                mreg.counter("serve_deadline_exceeded_total").inc()
                obs.event("serve_deadline", sl=sl,
                          deadline_s=self.deadline_s,
                          curtailed_tokens=curtailed)
                break
            if hedge_at is None and hedge_cutoff_s is not None:
                virtual = self._now() - exec_t0 + penalty
                if virtual > hedge_cutoff_s:
                    hedge_at = virtual
            t1 = self._now()
            with obs.span("serve/decode_token", pos=sl + step):
                def decode_once():
                    faults.fire("decode", self._decode_calls)
                    return self._decode(self.params, full, token,
                                        jnp.asarray(sl + step, jnp.int32))
                logits, full = retry_with_backoff(
                    decode_once, retries=self.policy.max_retries,
                    base_delay=self.policy.backoff_base_s,
                    factor=self.policy.backoff_factor,
                    max_delay_s=self.policy.max_delay_s,
                    jitter_frac=self.policy.jitter_frac,
                    jitter_seed=self.policy.jitter_seed,
                    label="serve_decode")
                self._decode_calls += 1
                decode_calls += 1
                penalty += penalty_per_call
                token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
                jax.block_until_ready(token)
            mreg.histogram("serve_decode_token_s", sl=sl).observe(
                self._now() - t1)
        decode_dt = self._now() - dec_t0 if n_steps else 0.0
        latency = self._now() - exec_t0 + penalty
        if hedge_at is None and hedge_cutoff_s is not None \
                and latency > hedge_cutoff_s:
            hedge_at = latency            # crossed after the last decode
        return {"outputs": outputs, "emitted": emitted,
                "decode_calls": decode_calls, "prefill_dt": prefill_dt,
                "decode_dt": decode_dt, "latency_s": latency,
                "penalty_s": penalty, "hedge_at": hedge_at,
                "deadline_hit": deadline_hit}

    # ------------------------------------------------------------------
    def run_batch(self, requests: List[Request]) -> List[Request]:
        """Prefill a batch of same-padded-SL requests, then decode.

        Pads the batch with dummy requests on a local copy only; the
        caller's list is never mutated and only the real requests are
        returned. Prefill's last-position logits supply the first generated
        token, so ``n_steps`` useful tokens cost ``n_steps - 1`` decode
        calls.

        Overload sheds instead of crashing: requests beyond ``batch_size``
        come back with ``shed=True`` and empty output for the caller to
        requeue. With ``deadline_s`` set, decode stops once the batch has
        used its budget (prefill included) and the remaining tokens are
        curtailed — latency SLO over completion; curtailed requests carry
        ``curtailed=True`` and the serve EpochLog records the count, so a
        partial answer is never mistaken for a completed one. Transient
        decode faults are retried with backoff (the injected ones fire
        before the jitted call, so no cache state is lost). With
        ``n_replicas > 1`` a batch running ``hedge_factor``× past its
        per-SL median baseline is hedged onto another replica; only the
        winning execution's tokens are committed and counted.

        Batch formation is delegated to the scheduler layer (an
        ``AdmissionQueue`` + ``FifoPolicy`` one-shot): this method is the
        run-to-completion compatibility wrapper around the same admission
        machinery the continuous ``serve()`` loop uses.
        """
        from repro.serve.sched.policy import FifoPolicy
        from repro.serve.sched.queue import AdmissionQueue

        mreg = obs.metrics
        mreg.gauge("serve_queue_depth").set(len(requests))
        q = AdmissionQueue(self.max_len, timer=self._now)
        tickets = {id(r): q.submit(r) for r in requests}
        picked = FifoPolicy().select(q.pending(), self.batch_size)
        q.take(picked)
        admitted = [t.req for t in picked]
        for r in requests:                                # shed-on-overload
            r.shed = tickets[id(r)] not in picked
        n_shed = len(requests) - len(admitted)
        if n_shed:
            mreg.counter("serve_shed_total").inc(n_shed)
            obs.event("serve_shed", count=n_shed, admitted=len(admitted))
        mreg.gauge("serve_batch_fill").set(len(admitted) / self.batch_size)
        batch_t0 = self._now()                            # deadline clock
        batch = list(admitted)
        while len(batch) < self.batch_size:               # pad batch
            batch.append(Request(prompt=np.zeros(4, np.int32),
                                 max_new_tokens=0))
        sl = self._pad(max(len(r.prompt) for r in batch))
        toks = np.zeros((self.batch_size, sl), np.int32)
        real_tokens = 0
        for i, r in enumerate(batch):
            prompt = r.prompt[-sl:]       # keep the most recent sl tokens
            if len(prompt):
                toks[i, -len(prompt):] = prompt
            if i < len(admitted):
                real_tokens += len(prompt)
        # fraction of the (batch, sl) prefill grid that is dummy/pad work
        waste = 1.0 - real_tokens / float(self.batch_size * sl)
        mreg.gauge("serve_padding_waste").set(waste)
        mreg.histogram("serve_padding_waste_frac", sl=sl).observe(waste)

        primary = self.replicas.pick_primary()
        baseline = self.latency_watchdog.baseline(sl)
        cutoff = self.hedge_factor * baseline \
            if baseline is not None and self.replicas.n > 1 else None
        result = self._execute(batch, len(admitted), toks, sl, batch_t0,
                               cutoff)
        winner, hedged = primary, False
        if result["hedge_at"] is not None:
            hedge_replica = self.replicas.pick_hedge(exclude=primary)
            mreg.counter("serve_hedges_total").inc()
            obs.event("hedge_fired", sl=sl, primary=primary,
                      hedge_replica=hedge_replica,
                      at_s=result["hedge_at"], baseline_s=baseline,
                      factor=self.hedge_factor)
            hedge = self._execute(batch, len(admitted), toks, sl, batch_t0,
                                  None)
            # the hedge starts at the detection instant, so its virtual
            # finish line is detection time + its own latency
            hedge_total = result["hedge_at"] + hedge["latency_s"]
            if hedge_total < result["latency_s"]:
                self.replicas.mark_slow(primary)
                self.replicas.mark_ok(hedge_replica)
                mreg.counter("serve_hedge_wins_total").inc()
                obs.event("hedge_won", sl=sl, winner=hedge_replica,
                          latency_s=hedge_total,
                          primary_latency_s=result["latency_s"])
                obs.event("hedge_cancelled", sl=sl, loser=primary,
                          wasted_tokens=result["emitted"])
                hedge["latency_s"] = hedge_total
                result, winner, hedged = hedge, hedge_replica, True
            else:
                self.replicas.mark_ok(primary)
                obs.event("hedge_cancelled", sl=sl, loser=hedge_replica,
                          wasted_tokens=hedge["emitted"])
        else:
            self.replicas.mark_ok(primary)

        # commit the winning execution only: the loser's tokens never reach
        # the caller or the tokens_out counter
        n_curtailed = 0
        for i, r in enumerate(admitted):
            r.output.extend(result["outputs"][i])
            r.curtailed = bool(result["deadline_hit"]
                               and len(r.output) < r.max_new_tokens)
            n_curtailed += int(r.curtailed)
        if n_curtailed:
            mreg.counter("serve_curtailed_total").inc(n_curtailed)
        latency = result["latency_s"]
        self.latency_watchdog.observe(sl, latency)
        mreg.histogram("serve_batch_latency_s", sl=sl).observe(latency)
        # tokens_out counts tokens actually emitted to real requests — not
        # requested tokens summed over the padded batch — so serve
        # throughput metrics stay honest under shedding, deadlines, and
        # hedging; curtailed distinguishes deadline-cut partials from
        # completed requests
        self.log.append(sl, result["prefill_dt"],
                        decode_s=result["decode_dt"],
                        decode_steps=float(result["decode_calls"]),
                        tokens_out=float(result["emitted"]),
                        latency_s=latency, hedged=float(hedged),
                        curtailed=float(n_curtailed),
                        replica=float(winner))
        return requests

    # ------------------------------------------------------------------
    def serve(self, requests: List[Request], *, policy=None,
              max_queue: Optional[int] = None):
        """Serve ``requests`` through the SL-aware continuous-batching
        scheduler (``repro.serve.sched``): SL-bucketed admission, slot
        admission at decode-step granularity, immediate eviction of
        finished sequences. Returns the run's ``ServeStats``.

        ``policy`` is any ``sched.policy.AdmissionPolicy`` (default:
        bucket-affine). Per-request log records land in ``self.log`` (one
        per request, keyed by its padded SL), so ``seqpoints()`` works on
        a scheduled trace exactly as on a run-to-completion one.
        """
        from repro.serve.sched.loop import ContinuousBatcher

        batcher = ContinuousBatcher(self, policy=policy,
                                    max_queue=max_queue)
        for r in requests:
            batcher.submit(r)
        return batcher.run()

    def seqpoints(self, **kw) -> SeqPointSet:
        return select_seqpoints(self.log, **kw)
