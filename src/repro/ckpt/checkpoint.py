"""Fault-tolerant checkpointing: atomic, versioned, async, resharding.

Layout:  <dir>/step_<N>/
            manifest.json   — step, flat param paths, shapes/dtypes, config
                              fingerprint, data-iterator state, sha256 of
                              each shard file
            arrays.npz      — flat {path: np.ndarray} (gathered host values)

Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a killed
writer never corrupts the latest checkpoint. ``keep_last`` prunes old steps.
``save_async`` snapshots to host memory synchronously (cheap) and writes on
a background thread so the train loop continues — the standard
fault-tolerance pattern at fleet scale.

Restore is *resharding*: arrays are loaded on host and ``jax.device_put``
with the (possibly different) target sharding, so a run checkpointed on one
mesh resumes on another (elastic scaling across pod counts).

Restore is also *defensive*: the manifest's recorded sha256 of
``arrays.npz`` is verified before anything is loaded, and a corrupt or
truncated step falls back to the previous ``step_<N>`` instead of killing
the resume (structural mismatches — wrong shapes, missing leaves — still
raise, because an older checkpoint would not fix those). Background-write
failures are captured and re-raised at the next ``wait()``/``save()``
rather than silently discovered at restore time.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
import zipfile
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import obs
from repro.resilience import faults

# errors that mean "this step's files are damaged" — safe to fall back past
# (injected TransientFault is deliberately NOT here: transient I/O should be
# retried on the same step by the caller, not skipped to an older state)
_DAMAGE = (IOError, OSError, EOFError, zipfile.BadZipFile,
           json.JSONDecodeError)

Params = Any


def _flatten(tree: Params) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(k.key) if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_like(tree: Params, flat: Dict[str, np.ndarray]) -> Params:
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    new_leaves = []
    for path, leaf in leaves_paths:
        key = "/".join(
            str(k.key) if hasattr(k, "key") else str(getattr(k, "idx", k))
            for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                manifest = os.path.join(self.dir, name, "manifest.json")
                if os.path.exists(manifest):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Params, *,
             extra: Optional[dict] = None) -> str:
        self.wait()             # surface any pending background-write error
        flat = _flatten(state)
        return self._write(step, flat, extra or {})

    def save_async(self, step: int, state: Params, *,
                   extra: Optional[dict] = None) -> None:
        """Snapshot synchronously (device->host), write in background.

        A failing background write is captured and re-raised at the next
        ``wait()``/``save()``/``save_async()`` (plus an immediate obs
        event), so a dying checkpoint disk shows up within one save
        interval, not at restore time.
        """
        self.wait()
        flat = _flatten(state)                        # blocking copy to host

        def work():
            try:
                self._write(step, flat, extra or {})
            except BaseException as e:                 # noqa: BLE001
                self._error = e
                obs.metrics.counter("ckpt_async_errors_total").inc()
                obs.event("ckpt_async_error", step=step, error=repr(e))

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, flat: Dict[str, np.ndarray],
               extra: dict) -> str:
        faults.fire("ckpt_save", step)
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **{k: v for k, v in flat.items()})
        with open(npz_path, "rb") as f:
            digest = hashlib.sha256(f.read()).hexdigest()
        if faults.check("ckpt_corrupt", step) is not None:
            # silent media corruption: damage the shard AFTER the digest is
            # recorded, so only restore-time verification can catch it
            with open(npz_path, "r+b") as f:
                f.seek(min(64, os.path.getsize(npz_path) - 4))
                f.write(b"\xde\xad\xbe\xef")
        manifest = {
            "step": step,
            "time": time.time(),
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
            "sha256": digest,
            "extra": extra,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()
        return final

    def _prune(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def verify_step(self, step: int) -> bool:
        """True iff ``step``'s shard matches its manifest-recorded sha256."""
        try:
            d = self._step_dir(step)
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            with open(os.path.join(d, "arrays.npz"), "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            return digest == manifest["sha256"]
        except _DAMAGE:
            return False

    def restore(self, like: Params, step: Optional[int] = None, *,
                shardings: Optional[Params] = None, verify: bool = True,
                fallback: Optional[bool] = None) -> Tuple[Params, dict]:
        """Load into the structure of ``like``; optionally device_put with
        target shardings (mesh may differ from the saving run).

        The shard sha256 is verified against the manifest before loading.
        With ``fallback`` (default: on when ``step`` is not pinned), a
        corrupt/truncated step is skipped and the previous ``step_<N>`` is
        tried, oldest-surviving wins; ``IOError`` only if none is usable.
        """
        faults.fire("ckpt_restore", -1 if step is None else step)
        steps = self.steps()
        if step is None:
            if not steps:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
            fallback = True if fallback is None else fallback
            candidates = list(reversed(steps))
        else:
            fallback = False if fallback is None else fallback
            candidates = [step] + [s for s in reversed(steps) if s < step]
        if not fallback:
            candidates = candidates[:1]
        last_err: Optional[BaseException] = None
        for s in candidates:
            try:
                return self._restore_step(s, like, shardings=shardings,
                                          verify=verify)
            except _DAMAGE as e:
                last_err = e
                obs.metrics.counter("ckpt_fallback_total").inc()
                obs.event("ckpt_restore_failed", step=s, error=repr(e),
                          will_fallback=s != candidates[-1])
                continue
        raise IOError(f"no usable checkpoint in {self.dir} "
                      f"(tried {candidates}): {last_err!r}")

    def _restore_step(self, step: int, like: Params, *,
                      shardings: Optional[Params],
                      verify: bool) -> Tuple[Params, dict]:
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        npz_path = os.path.join(d, "arrays.npz")
        if verify:
            with open(npz_path, "rb") as f:
                digest = hashlib.sha256(f.read()).hexdigest()
            if digest != manifest["sha256"]:
                raise IOError(f"checkpoint {d} corrupt (sha mismatch)")
        flat = dict(np.load(npz_path))
        state = _unflatten_like(like, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda a, s: jax.device_put(a, s), state, shardings)
        else:
            state = jax.tree.map(jax.numpy.asarray, state)
        return state, manifest["extra"]
