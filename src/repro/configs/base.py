"""Config dataclasses for the repro framework.

Every architecture is described by a ``ModelConfig``; runnable cells combine a
``ModelConfig`` with a ``ShapeConfig`` (seq_len x global_batch x step kind) and
a ``MeshConfig``. Configs are plain frozen dataclasses so they hash, compare,
and serialize trivially (the checkpoint manifest embeds them).
"""
from __future__ import annotations

import dataclasses
import enum
import json
from dataclasses import dataclass, field
from typing import Any, Optional, Sequence, Tuple


class BlockKind(str, enum.Enum):
    """Kind of a residual block in the layer stack."""

    ATTENTION = "attention"        # GQA/MHA self-attention
    MLA = "mla"                    # DeepSeek multi-head latent attention
    MAMBA = "mamba"                # Mamba-1 selective SSM (jamba)
    RWKV = "rwkv"                  # RWKV-6 time-mix (attention-free)
    DENSE_FFN = "dense_ffn"
    MOE_FFN = "moe_ffn"
    RWKV_CHANNEL = "rwkv_channel"  # RWKV-6 channel-mix


class StepKind(str, enum.Enum):
    TRAIN = "train"          # full fwd+bwd+update
    PREFILL = "prefill"      # fwd, build KV cache
    DECODE = "decode"        # one token vs. existing cache/state


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int            # top-k routed
    num_shared_experts: int = 0
    expert_d_ff: Optional[int] = None  # per-expert hidden dim (defaults d_ff)
    capacity_factor: float = 1.25      # capacity-bounded dispatch (TPU style)
    router_aux_coef: float = 0.001
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims (arXiv:2412.19437)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2                    # d_inner = expand * d_model


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec archs (whisper). Frontend is a stub that
    consumes precomputed frame embeddings per the assignment."""

    num_layers: int = 24
    max_source_len: int = 1500         # whisper: 30s @ 50 Hz after conv stub


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # moe|dense|vlm|hybrid|audio|ssm|rnn
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    num_heads: int = 0                 # 0 => attention-free arch
    num_kv_heads: int = 0
    head_dim: int = 0                  # 0 => d_model // num_heads
    # Layer pattern: sequence of (mixer kind, ffn kind) repeated over depth.
    # Default: uniform attention + ffn. jamba overrides with 1:7 attn:mamba.
    block_pattern: Tuple[Tuple[BlockKind, BlockKind], ...] = ()
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    encoder: Optional[EncoderConfig] = None
    # --- attention details ---
    rope_theta: float = 10000.0
    qkv_bias: bool = False             # qwen2 uses QKV bias
    causal: bool = True
    max_position: int = 131072
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # multi-token prediction heads (deepseek-v3 MTP); 0 = disabled
    mtp_depth: int = 0
    # modality frontend stub: number of embedding inputs replacing tokens
    frontend: Optional[str] = None     # None | "audio_frames" | "image_patches"
    act: str = "silu"
    # rwkv6 specifics
    rwkv_head_dim: int = 64

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads:
            return self.d_model // self.num_heads
        return 0

    @property
    def pattern(self) -> Tuple[Tuple[BlockKind, BlockKind], ...]:
        if self.block_pattern:
            return self.block_pattern
        mixer = BlockKind.ATTENTION
        ffn = BlockKind.MOE_FFN if self.moe is not None else BlockKind.DENSE_FFN
        return ((mixer, ffn),)

    @property
    def interleave_period(self) -> int:
        return len(self.pattern)

    @property
    def attention_free(self) -> bool:
        kinds = {m for m, _ in self.pattern}
        return BlockKind.ATTENTION not in kinds and BlockKind.MLA not in kinds

    @property
    def subquadratic(self) -> bool:
        """True when the arch can serve 500k-token contexts (SSM/hybrid/linear)."""
        kinds = {m for m, _ in self.pattern}
        if kinds & {BlockKind.MAMBA, BlockKind.RWKV}:
            return True
        return False

    def with_overrides(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    def to_json(self) -> str:
        def enc(o: Any) -> Any:
            if isinstance(o, enum.Enum):
                return o.value
            if dataclasses.is_dataclass(o):
                return dataclasses.asdict(o)
            raise TypeError(o)

        return json.dumps(dataclasses.asdict(self), default=enc, sort_keys=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: StepKind
    # decode shapes: KV cache holds seq_len tokens, one new token is decoded.
    # enc-dec: source_len drives the encoder, seq_len the decoder.
    source_len: int = 0


@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n

    @property
    def data_degree(self) -> int:
        d = 1
        for s, a in zip(self.shape, self.axes):
            if a in ("pod", "data"):
                d *= s
        return d

    @property
    def model_degree(self) -> int:
        for s, a in zip(self.shape, self.axes):
            if a == "model":
                return s
        return 1


SINGLE_POD = MeshConfig(shape=(16, 16), axes=("data", "model"))
MULTI_POD = MeshConfig(shape=(2, 16, 16), axes=("pod", "data", "model"))


@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # dtype of first/second moments. bf16 moments let deepseek-v3-scale
    # optimizer state fit the pod (see DESIGN.md §8.4).
    moment_dtype: str = "float32"
    # gradient all-reduce compression: none | bf16 | int8_ef
    grad_compression: str = "none"


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = SINGLE_POD
    optimizer: OptimizerConfig = OptimizerConfig()
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # fsdp: shard params + optimizer state over the data axis too (ZeRO-3-ish)
    fsdp: bool = False
    # extend FSDP across the pod (DCN) axis — needed for >100B archs
    fsdp_over_pods: bool = False
    # 3 = params+grads+opt sharded (gathers per microbatch);
    # 1 = opt state only (params TP-resident; one gather/reduce per step)
    zero_stage: int = 3
    remat: str = "none"                # none | block | full
    microbatches: int = 1              # gradient accumulation
    seed: int = 0
    # scan unrolling for dry-run cost analysis (see DESIGN.md §6)
    unroll_layers: int = 0             # 0 = rolled lax.scan
    attn_chunk: int = 0                # 0 = auto (chunked above threshold)
    use_pallas: bool = False           # TPU fast path (interpret in tests)
    # --- beyond-paper perf options (EXPERIMENTS.md §Perf) ---
    # experts sharded over (data x model) with a2a dispatch (hillclimb 1)
    moe_full_ep: bool = False
    # "tp" (default) | "dp_only": map the whole mesh to data parallelism
    # (hillclimb: small attention-free archs where TP overhead dominates)
    parallelism: str = "tp"
