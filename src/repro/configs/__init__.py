"""Config registry: ``get_model_config(arch_id)`` + smoke reductions."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from repro.configs.archs import ASSIGNED
from repro.configs.base import (
    MULTI_POD,
    SINGLE_POD,
    BlockKind,
    EncoderConfig,
    MLAConfig,
    MambaConfig,
    MeshConfig,
    MoEConfig,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    StepKind,
)
from repro.configs.shapes import ALL_SHAPES, get_shape, shapes_for

_REGISTRY: Dict[str, ModelConfig] = {m.name: m for m in ASSIGNED}


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def get_model_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return _REGISTRY[name]


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def smoke_config(name: str) -> ModelConfig:
    """Structure-preserving reduction for CPU smoke tests.

    Keeps the block pattern, family and every architectural mechanism (MoE,
    MLA, mamba, rwkv, enc-dec) while shrinking widths/depths/tables so a
    forward+backward step runs in well under a second on one CPU core.
    """
    cfg = get_model_config(name)
    period = cfg.interleave_period
    reduced = dict(
        num_layers=max(2 * period, 2),
        d_model=128,
        d_ff=256,
        vocab_size=512,
        max_position=4096,
    )
    if cfg.num_heads:
        reduced.update(num_heads=4, head_dim=32,
                       num_kv_heads=min(cfg.num_kv_heads, 4) or 4)
        # preserve the GQA grouping (kv < q) where the full arch has it
        if cfg.num_kv_heads < cfg.num_heads:
            reduced["num_kv_heads"] = 2
    if cfg.moe is not None:
        reduced["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8,
            experts_per_token=min(cfg.moe.experts_per_token, 2),
            expert_d_ff=128)
    if cfg.mla is not None:
        reduced["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                   qk_nope_head_dim=32, qk_rope_head_dim=16,
                                   v_head_dim=32)
    if cfg.mamba is not None:
        reduced["mamba"] = MambaConfig(d_state=8, d_conv=4, expand=2)
    if cfg.encoder is not None:
        reduced["encoder"] = EncoderConfig(num_layers=2, max_source_len=64)
    return cfg.with_overrides(name=f"{name}-smoke", **reduced)


__all__ = [
    "ALL_SHAPES", "ASSIGNED", "BlockKind", "EncoderConfig", "MLAConfig",
    "MambaConfig", "MeshConfig", "MoEConfig", "ModelConfig", "MULTI_POD",
    "OptimizerConfig", "RunConfig", "ShapeConfig", "SINGLE_POD", "StepKind",
    "get_model_config", "get_shape", "list_archs", "register", "shapes_for",
    "smoke_config",
]
