"""The ten assigned architectures, exact published dims.

Sources per the assignment block ([arXiv/hf; tier] annotations there). Each
config is consumed by ``repro.models.model_zoo.build_model``.
"""
from __future__ import annotations

from repro.configs.base import (
    BlockKind as BK,
    EncoderConfig,
    MLAConfig,
    MambaConfig,
    MoEConfig,
    ModelConfig,
)

# --- deepseek-v3-671b [arXiv:2412.19437] -----------------------------------
# MLA attention (latent kv), 1 shared + 256 routed experts top-8, MTP head.
# Assignment pins d_ff=2048 (the MoE expert intermediate); every layer is MoE
# per the assignment string (the HF release keeps 3 dense lead-in layers —
# noted in DESIGN.md §8).
DEEPSEEK_V3_671B = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, d_ff=2048, vocab_size=129_280,
    num_heads=128, num_kv_heads=128, head_dim=128,
    moe=MoEConfig(num_experts=256, experts_per_token=8, num_shared_experts=1,
                  expert_d_ff=2048),
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    block_pattern=((BK.MLA, BK.MOE_FFN),),
    mtp_depth=1, rope_theta=10_000.0,
)

# --- qwen2-moe-a2.7b [hf:Qwen/Qwen1.5-MoE-A2.7B] ---------------------------
QWEN2_MOE_A2_7B = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    num_layers=24, d_model=2048, d_ff=1408, vocab_size=151_936,
    num_heads=16, num_kv_heads=16,
    moe=MoEConfig(num_experts=60, experts_per_token=4, num_shared_experts=4,
                  expert_d_ff=1408),
    qkv_bias=True, rope_theta=1_000_000.0,
)

# --- mistral-nemo-12b [hf:mistralai/Mistral-Nemo-Base-2407] ----------------
# head_dim=128 is decoupled from d_model (32 heads x 128 = 4096 != 5120).
MISTRAL_NEMO_12B = ModelConfig(
    name="mistral-nemo-12b", family="dense",
    num_layers=40, d_model=5120, d_ff=14_336, vocab_size=131_072,
    num_heads=32, num_kv_heads=8, head_dim=128,
    rope_theta=1_000_000.0, max_position=131_072,
)

# --- internlm2-20b [arXiv:2403.17297] --------------------------------------
INTERNLM2_20B = ModelConfig(
    name="internlm2-20b", family="dense",
    num_layers=48, d_model=6144, d_ff=16_384, vocab_size=92_544,
    num_heads=48, num_kv_heads=8,
    rope_theta=1_000_000.0,
)

# --- qwen2-72b [arXiv:2407.10671] ------------------------------------------
QWEN2_72B = ModelConfig(
    name="qwen2-72b", family="dense",
    num_layers=80, d_model=8192, d_ff=29_568, vocab_size=152_064,
    num_heads=64, num_kv_heads=8, qkv_bias=True,
    rope_theta=1_000_000.0,
)

# --- starcoder2-3b [arXiv:2402.19173] --------------------------------------
STARCODER2_3B = ModelConfig(
    name="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, d_ff=12_288, vocab_size=49_152,
    num_heads=24, num_kv_heads=2,
    rope_theta=999_999.4,
)

# --- llava-next-34b [hf:llava-hf/llava-v1.6-*] -----------------------------
# VLM: transformer backbone only; anyres image patches arrive as precomputed
# patch embeddings through the frontend stub (assignment rule).
LLAVA_NEXT_34B = ModelConfig(
    name="llava-next-34b", family="vlm",
    num_layers=60, d_model=7168, d_ff=20_480, vocab_size=64_000,
    num_heads=56, num_kv_heads=8,
    rope_theta=5_000_000.0, frontend="image_patches",
)

# --- jamba-v0.1-52b [arXiv:2403.19887] -------------------------------------
# Mamba:attention 7:1 (attn at offset 4 of every 8), MoE every other layer
# (offset 1 of every 2), 16 experts top-2.
_JAMBA_PATTERN = tuple(
    (BK.ATTENTION if i == 4 else BK.MAMBA,
     BK.MOE_FFN if i % 2 == 1 else BK.DENSE_FFN)
    for i in range(8)
)
JAMBA_V01_52B = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, d_ff=14_336, vocab_size=65_536,
    num_heads=32, num_kv_heads=8,
    block_pattern=_JAMBA_PATTERN,
    moe=MoEConfig(num_experts=16, experts_per_token=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
)

# --- whisper-medium [arXiv:2212.04356] -------------------------------------
# Enc-dec; conv frontend is a stub feeding precomputed frame embeddings
# (1500 frames = 30 s). num_layers counts decoder layers; the encoder stack is
# symmetric (24 layers).
WHISPER_MEDIUM = ModelConfig(
    name="whisper-medium", family="audio",
    num_layers=24, d_model=1024, d_ff=4096, vocab_size=51_865,
    num_heads=16, num_kv_heads=16,
    encoder=EncoderConfig(num_layers=24, max_source_len=1500),
    frontend="audio_frames", act="gelu", max_position=40_960,
)

# --- rwkv6-3b (Finch) [arXiv:2404.05892] -----------------------------------
# Attention-free: time-mix with data-dependent decay + channel-mix.
RWKV6_3B = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, d_ff=8960, vocab_size=65_536,
    num_heads=0, num_kv_heads=0,
    block_pattern=((BK.RWKV, BK.RWKV_CHANNEL),),
    rwkv_head_dim=64,
)

ASSIGNED = (
    DEEPSEEK_V3_671B, QWEN2_MOE_A2_7B, MISTRAL_NEMO_12B, INTERNLM2_20B,
    QWEN2_72B, STARCODER2_3B, LLAVA_NEXT_34B, JAMBA_V01_52B,
    WHISPER_MEDIUM, RWKV6_3B,
)
