"""Assigned input-shape sets (see assignment block / DESIGN.md).

LM transformer shapes are seq_len x global_batch. ``decode_*`` / ``long_*``
lower ``serve_step`` (one new token against a KV cache of seq_len), not
``train_step``. ``long_500k`` applies only to sub-quadratic archs.
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import ModelConfig, ShapeConfig, StepKind

TRAIN_4K = ShapeConfig("train_4k", seq_len=4_096, global_batch=256,
                       step=StepKind.TRAIN)
PREFILL_32K = ShapeConfig("prefill_32k", seq_len=32_768, global_batch=32,
                          step=StepKind.PREFILL)
DECODE_32K = ShapeConfig("decode_32k", seq_len=32_768, global_batch=128,
                         step=StepKind.DECODE)
LONG_500K = ShapeConfig("long_500k", seq_len=524_288, global_batch=1,
                        step=StepKind.DECODE)

ALL_SHAPES: Dict[str, ShapeConfig] = {
    s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
}


def shapes_for(model: ModelConfig) -> List[ShapeConfig]:
    """The assigned shape cells for one architecture.

    ``long_500k`` needs sub-quadratic sequence mixing; pure full-attention
    archs skip it (recorded in DESIGN.md §7). Enc-dec archs have a decoder, so
    decode shapes run.
    """
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if model.subquadratic:
        out.append(LONG_500K)
    return out


def get_shape(name: str) -> ShapeConfig:
    return ALL_SHAPES[name]
