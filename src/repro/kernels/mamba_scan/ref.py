"""Sequential oracle for the selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(x, delta, a, b, c, d):
    """x/delta: (B, S, D); a: (D, N); b/c: (B, S, N); d: (D,)."""
    bsz, s, dim = x.shape
    f32 = jnp.float32
    x32, delta32 = x.astype(f32), delta.astype(f32)

    def step(h, xs):
        xt, dt, bt, ct = xs
        da = jnp.exp(dt[..., None] * a.astype(f32))
        dbx = (dt * xt)[..., None] * bt[:, None, :]
        h = da * h + dbx
        y = jnp.sum(h * ct[:, None, :], axis=-1) + d.astype(f32) * xt
        return h, y

    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(delta32, 1, 0),
          jnp.moveaxis(b.astype(f32), 1, 0), jnp.moveaxis(c.astype(f32), 1, 0))
    h0 = jnp.zeros((bsz, dim, a.shape[1]), f32)
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype)
