"""Mamba selective-scan kernel (Pallas TPU).

Grid (B, d_inner/BD, S/C): channel blocks are parallel; the sequence-chunk
axis is innermost-sequential with the (BD, N) SSM state in fp32 VMEM
scratch. The discretized (dA, dBx) terms are formed *inside* the kernel from
(delta, A, B, C, x) — the (B, S, D, N) expansion that makes the pure-XLA
associative-scan path memory-hungry never touches HBM. This is the
TPU-native restatement of the CUDA selective-scan's SRAM strategy
(DESIGN.md §3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _mamba_kernel(x_ref, delta_ref, a_ref, b_ref, c_ref, d_ref, y_ref,
                  h_scr, *, chunk: int, n_state: int):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)            # (C, BD)
    delta = delta_ref[0].astype(jnp.float32)    # (C, BD)
    a = a_ref[...].astype(jnp.float32)          # (BD, N)
    bm = b_ref[0].astype(jnp.float32)           # (C, N)
    cm = c_ref[0].astype(jnp.float32)           # (C, N)
    dd = d_ref[...].astype(jnp.float32)         # (BD,)

    def step(t, carry):
        h, ys = carry
        da = jnp.exp(delta[t][:, None] * a)                 # (BD, N)
        dbx = (delta[t] * x[t])[:, None] * bm[t][None, :]   # (BD, N)
        h = da * h + dbx
        y_t = jnp.sum(h * cm[t][None, :], axis=1) + dd * x[t]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, 0)
        return h, ys

    ys0 = jnp.zeros((chunk, x.shape[1]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h_scr[...], ys0))
    h_scr[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def mamba_scan_fwd(x: jax.Array, delta: jax.Array, a: jax.Array,
                   b: jax.Array, c: jax.Array, d: jax.Array, *,
                   block_d: int = 256, chunk: int = 64,
                   interpret: bool = False) -> jax.Array:
    """x/delta: (B, S, D); a: (D, N); b/c: (B, S, N); d: (D,) -> y (B,S,D)."""
    bsz, s, dim = x.shape
    n = a.shape[1]
    bd = min(block_d, dim)
    chunk = min(chunk, s)
    assert dim % bd == 0 and s % chunk == 0
    kernel = functools.partial(_mamba_kernel, chunk=chunk, n_state=n)
    xspec = pl.BlockSpec((1, chunk, bd), lambda i, j, t: (i, t, j))
    nspec = pl.BlockSpec((1, chunk, n), lambda i, j, t: (i, t, 0))
    return pl.pallas_call(
        kernel,
        grid=(bsz, dim // bd, s // chunk),
        in_specs=[
            xspec, xspec,
            pl.BlockSpec((bd, n), lambda i, j, t: (j, 0)),
            nspec, nspec,
            pl.BlockSpec((bd,), lambda i, j, t: (j,)),
        ],
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct((bsz, s, dim), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, delta, a, b, c, d)
