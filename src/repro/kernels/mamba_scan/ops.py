"""Jitted wrapper + custom VJP (backward via reference scan)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan.kernel import mamba_scan_fwd
from repro.kernels.mamba_scan.ref import mamba_scan_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def mamba_scan(x, delta, a, b, c, d, block_d: int = 256, chunk: int = 64):
    interpret = jax.default_backend() != "tpu"
    return mamba_scan_fwd(x, delta, a, b, c, d, block_d=block_d, chunk=chunk,
                          interpret=interpret)


def _fwd(x, delta, a, b, c, d, block_d, chunk):
    return mamba_scan(x, delta, a, b, c, d, block_d, chunk), \
        (x, delta, a, b, c, d)


def _bwd(block_d, chunk, res, g):
    _, vjp = jax.vjp(mamba_scan_ref, *res)
    return vjp(g)


mamba_scan.defvjp(_fwd, _bwd)
