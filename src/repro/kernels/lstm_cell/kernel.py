"""Fused LSTM cell kernel (Pallas TPU) — the paper's GNMT hot loop.

One timestep over the batch: z = [x; h] @ W + b followed by the four-gate
state update, fused so gate preactivations never round-trip to HBM (the
MIOpen kernels the paper profiles do the same on GPU; DESIGN.md §3).

Weights are laid out (D+H, H, 4) so a hidden-column block carries all four
gates for its units. Grid: (B/BB, H/BH); the contraction dimension stays
resident in VMEM (recurrent weights are the reuse case persistent-RNN
papers optimize).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _lstm_kernel(xh_ref, w_ref, b_ref, c_ref, h_out_ref, c_out_ref):
    xh = xh_ref[...].astype(jnp.float32)            # (BB, D+H)
    bb, dh_in = xh.shape
    w = w_ref[...].astype(jnp.float32)              # (D+H, BH, 4)
    bias = b_ref[...].astype(jnp.float32)           # (BH, 4)
    c = c_ref[...].astype(jnp.float32)              # (BB, BH)
    bh = w.shape[1]
    z = jax.lax.dot_general(
        xh, w.reshape(dh_in, bh * 4), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).reshape(bb, bh, 4) \
        + bias[None]
    i, f, g, o = z[..., 0], z[..., 1], z[..., 2], z[..., 3]
    c_new = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    h_out_ref[...] = h_new.astype(h_out_ref.dtype)
    c_out_ref[...] = c_new.astype(c_out_ref.dtype)


def lstm_cell_fwd(xh: jax.Array, w: jax.Array, b: jax.Array, c: jax.Array, *,
                  block_b: int = 128, block_h: int = 128,
                  interpret: bool = False):
    """xh: (B, D+H) concat of input and previous hidden; w: (D+H, H, 4);
    b: (H, 4); c: (B, H). Returns (h_new, c_new)."""
    bsz, dh_in = xh.shape
    h = w.shape[1]
    bb = min(block_b, bsz)
    bh = min(block_h, h)
    assert bsz % bb == 0 and h % bh == 0
    return pl.pallas_call(
        _lstm_kernel,
        grid=(bsz // bb, h // bh),
        in_specs=[
            pl.BlockSpec((bb, dh_in), lambda i, j: (i, 0)),
            pl.BlockSpec((dh_in, bh, 4), lambda i, j: (0, j, 0)),
            pl.BlockSpec((bh, 4), lambda i, j: (j, 0)),
            pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((bb, bh), lambda i, j: (i, j)),
                   pl.BlockSpec((bb, bh), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((bsz, h), xh.dtype),
                   jax.ShapeDtypeStruct((bsz, h), xh.dtype)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xh, w, b, c)
