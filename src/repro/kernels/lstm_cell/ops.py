"""Jitted wrapper; whole-sequence runner built on the fused cell."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.lstm_cell.kernel import lstm_cell_fwd
from repro.kernels.lstm_cell.ref import lstm_cell_ref


def lstm_cell(xh, w, b, c, block_b: int = 128, block_h: int = 128):
    interpret = jax.default_backend() != "tpu"
    return lstm_cell_fwd(xh, w, b, c, block_b=block_b, block_h=block_h,
                         interpret=interpret)


def lstm_sequence(xs, h0, c0, w, b, use_kernel: bool = True):
    """xs: (B, S, D); returns hidden states (B, S, H)."""
    cell = lstm_cell if use_kernel else lstm_cell_ref

    def step(carry, x):
        h, c = carry
        xh = jnp.concatenate([x, h], axis=-1)
        h, c = cell(xh, w, b, c)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, (h0, c0), jnp.moveaxis(xs, 1, 0))
    return jnp.moveaxis(hs, 0, 1)
