"""Oracle for the fused LSTM cell."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lstm_cell_ref(xh, w, b, c):
    """xh: (B, D+H); w: (D+H, H, 4); b: (H, 4); c: (B, H)."""
    z = jnp.einsum("bd,dhg->bhg", xh.astype(jnp.float32),
                   w.astype(jnp.float32)) + b.astype(jnp.float32)[None]
    i, f, g, o = z[..., 0], z[..., 1], z[..., 2], z[..., 3]
    c_new = jax.nn.sigmoid(f + 1.0) * c.astype(jnp.float32) \
        + jax.nn.sigmoid(i) * jnp.tanh(g)
    h_new = jax.nn.sigmoid(o) * jnp.tanh(c_new)
    return h_new.astype(xh.dtype), c_new.astype(xh.dtype)
