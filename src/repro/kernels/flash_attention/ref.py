"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True) -> jax.Array:
    """q: (BH, Sq, dh); k/v: (BHkv, Skv, dh). GQA by head repetition."""
    bh, sq, dh = q.shape
    bhkv, skv, _ = k.shape
    if bhkv != bh:
        k = jnp.repeat(k, bh // bhkv, axis=0)
        v = jnp.repeat(v, bh // bhkv, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(dh)
    if causal:
        mask = jnp.arange(skv)[None, :] <= jnp.arange(sq)[:, None]
        s = jnp.where(mask[None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
