"""Flash attention forward kernel (Pallas TPU).

Online-softmax over KV blocks with VMEM accumulators. Grid is
(batch*q_heads, Sq/BQ, Skv/BK); the KV dimension is the innermost
("arbitrary") axis so the fp32 scratch accumulators persist across KV steps
for a fixed (bh, q-block). Causal blocks entirely above the diagonal are
skipped via ``pl.when`` — the waste the pure-XLA chunked path cannot avoid
(DESIGN.md §6 hillclimb notes). GQA is folded into the index maps: the KV
block index map points query head h at KV head h // group.

Block shapes are MXU-aligned (BQ, BK multiples of 128 when Sq/Skv allow;
head_dim is the lane dimension).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                      scale: float, causal: bool, bq: int, bk: int,
                      n_k: int):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # kv block

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = i * bq
    k_start = j * bk
    run = True
    if causal:
        run = k_start <= q_start + bq - 1

    @pl.when(run if causal else True)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (BQ, dh)
        k = k_ref[0].astype(jnp.float32)            # (BK, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, block_q: int = 128,
                        block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, dh); k/v: (BHkv, Skv, dh) with BH % BHkv == 0."""
    bh, sq, dh = q.shape
    bhkv, skv, _ = k.shape
    group = bh // bhkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    n_q, n_k = sq // bq, skv // bk
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(_flash_fwd_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, n_k=n_k)
    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j, g=group: (b // g, j, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, j, g=group: (b // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
