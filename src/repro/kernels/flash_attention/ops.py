"""Jitted public wrapper: (B, S, H, dh) layout, GQA, custom VJP.

Forward runs the Pallas kernel (interpret=True off-TPU); backward falls back
to the jnp reference (correct everywhere; a fused backward kernel is the
natural next step and is noted in EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _fold(x: jax.Array) -> jax.Array:                 # (B,S,H,d) -> (BH,S,d)
    b, s, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)


def _unfold(x: jax.Array, b: int) -> jax.Array:
    bh, s, d = x.shape
    return x.reshape(b, bh // b, s, d).transpose(0, 2, 1, 3)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """q: (B, Sq, Hq, dh); k/v: (B, Skv, Hkv, dh); returns (B, Sq, Hq, dh)."""
    interpret = jax.default_backend() != "tpu"
    out = flash_attention_fwd(_fold(q), _fold(k), _fold(v), causal=causal,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return _unfold(out, q.shape[0])


def _fwd(q, k, v, causal, block_q, block_k):
    return flash_attention(q, k, v, causal, block_q, block_k), (q, k, v)


def _bwd(causal, block_q, block_k, res, g):
    q, k, v = res

    def ref(q, k, v):
        b = q.shape[0]
        return _unfold(attention_ref(_fold(q), _fold(k), _fold(v),
                                     causal=causal), b)

    _, vjp = jax.vjp(ref, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
