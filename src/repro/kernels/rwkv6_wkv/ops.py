"""Jitted wrapper for the WKV6 kernel: (B, S, H, dh) layout + custom VJP
(backward via the reference recurrence)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6_wkv.kernel import wkv6_fwd
from repro.kernels.rwkv6_wkv.ref import wkv6_ref


def _fold(x):                                      # (B,S,H,d) -> (BH,S,d)
    b, s, h, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def wkv6(r, k, v, lw, u, chunk: int = 64):
    """r/k/v/lw: (B, S, H, dh); u: (H, dh)."""
    b, s, h, dh = r.shape
    interpret = jax.default_backend() != "tpu"
    u_full = jnp.broadcast_to(u[None], (b, h, dh)).reshape(b * h, dh)
    y = wkv6_fwd(_fold(r), _fold(k), _fold(v), _fold(lw), u_full,
                 chunk=chunk, interpret=interpret)
    return y.reshape(b, h, s, dh).transpose(0, 2, 1, 3)


def _fwd(r, k, v, lw, u, chunk):
    return wkv6(r, k, v, lw, u, chunk), (r, k, v, lw, u)


def _bwd(chunk, res, g):
    r, k, v, lw, u = res

    def ref(r, k, v, lw, u):
        b, s, h, dh = r.shape
        u_full = jnp.broadcast_to(u[None], (b, h, dh)).reshape(b * h, dh)
        y = wkv6_ref(_fold(r), _fold(k), _fold(v), _fold(lw), u_full)
        return y.reshape(b, h, s, dh).transpose(0, 2, 1, 3)

    _, vjp = jax.vjp(ref, r, k, v, lw, u)
    return vjp(g)


wkv6.defvjp(_fwd, _bwd)
