"""Sequential oracle for the WKV6 kernel (same recurrence as
repro.models.rwkv.wkv6_sequential, flattened-head layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, lw, u):
    """r/k/v/lw: (BH, S, dh); u: (BH, dh). y_t = r_t.(S_{t-1} + u k_t v_t^T);
    S_t = diag(exp(lw_t)) S_{t-1} + k_t v_t^T."""
    bh, s, dh = r.shape
    w = jnp.exp(lw.astype(jnp.float32))

    def step(st, xs):
        rt, kt, vt, wt = xs
        y = jnp.einsum("bk,bkv->bv", rt, st) + \
            jnp.einsum("bk,bk,bv->bv", rt, u * kt, vt)
        st = wt[..., None] * st + kt[..., None] * vt[..., None, :]
        return st, y

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (r, k, v, w))
    st0 = jnp.zeros((bh, dh, dh), jnp.float32)
    _, ys = jax.lax.scan(step, st0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype)
