"""RWKV-6 WKV kernel (Pallas TPU): chunked linear attention with
data-dependent per-channel decay.

Grid is (B*H, S/C) with the chunk axis innermost-sequential ("arbitrary"):
the per-head (dh x dh) state lives in fp32 VMEM scratch and is carried
across chunk steps — the TPU-native replacement for the CUDA wkv kernels
(DESIGN.md §3). Within a chunk everything is dense (C x C) MXU work:

  y_i = r~_i @ S_in + sum_{j<i} (r~_i . k~_j) v_j + (r_i . u k_i) v_i
  S_out = exp(total) * S_in + (k * exp(total - cs))^T V

with r~ = r * exp(cs_{i-1}), k~ = k * exp(-cs_j) (log-decays clamped to
[-1, 0) as in the model code, so exp(-cs) fits fp32 for C <= 64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# pallas renamed TPUCompilerParams -> CompilerParams across jax versions
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_scr, *,
                 chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0].astype(jnp.float32)                # (C, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0, 0].astype(jnp.float32)             # (dh,)

    cs = jnp.cumsum(lw, axis=0)                     # inclusive
    total = cs[-1]                                  # (dh,)
    rq = r * jnp.exp(cs - lw)                       # r~ (decay to i-1)
    kk = k * jnp.exp(-cs)                           # k~
    att = jax.lax.dot_general(rq, kk, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(ii > jj, att, 0.0)
    diag = jnp.sum(r * (u[None, :] * k), axis=1)    # (C,)
    y = (jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
         + diag[:, None] * v
         + jax.lax.dot_general(rq, s_scr[...], (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)

    kdec = k * jnp.exp(total[None, :] - cs)
    s_scr[...] = (s_scr[...] * jnp.exp(total)[:, None]
                  + jax.lax.dot_general(kdec, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))


def wkv6_fwd(r: jax.Array, k: jax.Array, v: jax.Array, lw: jax.Array,
             u: jax.Array, *, chunk: int = 64,
             interpret: bool = False) -> jax.Array:
    """r/k/v/lw: (BH, S, dh) fp32-ish; u: (BH, dh). Returns y: (BH, S, dh)."""
    bh, s, dh = r.shape
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    kernel = functools.partial(_wkv6_kernel, chunk=chunk)
    spec = pl.BlockSpec((1, chunk, dh), lambda b, j: (b, j, 0))
    return pl.pallas_call(
        kernel,
        grid=(bh, n),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1, 1, dh), lambda b, j: (b, 0, 0))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), r.dtype),
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, lw, u[:, None, :])
