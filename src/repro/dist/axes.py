"""Named logical axes over the physical mesh (DESIGN.md §5).

Model code never names physical mesh axes: it constrains activations along
*logical* axes ("dp" for the batch dims, "tp" for tensor-parallel dims) and
this module resolves them against whatever mesh is active.  Resolution is
scoped: the launcher can retarget "dp" (e.g. ``parallelism="dp_only"`` maps
the whole mesh onto the batch) with ``set_dp_axes``, either as a plain call
or as a context manager that restores the previous mapping on exit.

``constrain`` is a no-op when no mesh is active, so single-device smoke
paths and jit tracing outside a mesh context run unchanged.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# Logical axis -> physical mesh axes it may map onto (filtered to the axes
# actually present on the active mesh). "dp" can be rescoped via
# ``set_dp_axes``; the rest are fixed vocabulary.
_DEFAULT_LOGICAL = {
    "dp": ("pod", "data"),       # data parallelism (batch dims)
    "tp": ("model",),            # tensor parallelism (feature/head dims)
    "ep": ("data", "model"),     # full expert parallelism (moe_full_ep)
}

_dp_override: Optional[Tuple[str, ...]] = None


class _DpScope:
    """Token returned by ``set_dp_axes``; optionally used as a context
    manager to restore the previous mapping."""

    def __init__(self, prev: Optional[Tuple[str, ...]]):
        self._prev = prev

    def __enter__(self) -> "_DpScope":
        return self

    def __exit__(self, *exc) -> bool:
        global _dp_override
        _dp_override = self._prev
        return False


def set_dp_axes(axes: Optional[Sequence[str]]) -> _DpScope:
    """Retarget the "dp" logical axis to ``axes`` (``None`` restores the
    default ("pod", "data") mapping). Returns a scope token usable as a
    context manager."""
    global _dp_override
    prev = _dp_override
    _dp_override = tuple(axes) if axes is not None else None
    return _DpScope(prev)


def dp_axes() -> Tuple[str, ...]:
    return _dp_override if _dp_override is not None \
        else _DEFAULT_LOGICAL["dp"]


def active_mesh():
    """The physical mesh of the enclosing ``with mesh:`` scope, or ``None``.

    Works at trace time: ``jax.jit`` bodies traced inside a mesh context see
    the mesh through the thread-local resource env.
    """
    from jax._src.mesh import thread_resources

    m = thread_resources.env.physical_mesh
    return None if m.empty else m


def current_mesh_axes() -> Tuple[str, ...]:
    """Axis names of the active mesh; ``()`` when no mesh is active."""
    m = active_mesh()
    return tuple(m.axis_names) if m is not None else ()


def _resolve(logical: Optional[str],
             mesh_axes: Optional[Sequence[str]] = None) -> Tuple[str, ...]:
    """Logical name -> tuple of physical axes present on the (active) mesh.

    Unknown names pass through as a physical axis name, so callers may mix
    vocabularies ("dp" and "data" both work).
    """
    if logical is None:
        return ()
    if mesh_axes is None:
        mesh_axes = current_mesh_axes()
    if logical == "dp":
        phys = dp_axes()
    else:
        phys = _DEFAULT_LOGICAL.get(logical, (logical,))
    return tuple(a for a in phys if a in mesh_axes)


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Apply ``with_sharding_constraint`` along logical axes when a mesh is
    active; identity otherwise.

    One logical name (or ``None``) per array dim. A dim is left unsharded
    when its logical axis resolves to nothing on the mesh or its size does
    not divide by the resolved axes' total extent — so the same model code
    is valid on every mesh (including none).
    """
    m = active_mesh()
    if m is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(
            f"constrain: {len(logical_axes)} logical axes for rank-{x.ndim} "
            f"array {x.shape}")
    mesh_axes = tuple(m.axis_names)
    entries = []
    for dim, name in zip(x.shape, logical_axes):
        phys = _resolve(name, mesh_axes)
        extent = 1
        for a in phys:
            extent *= m.shape[a]
        if not phys or extent <= 1 or dim % extent != 0:
            entries.append(None)
        else:
            entries.append(phys[0] if len(phys) == 1 else phys)
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*entries)))
