"""Error-feedback gradient compression for the data-parallel all-reduce.

The DP gradient all-reduce moves one full parameter-sized buffer per step;
at production scale it is the dominant DCN/ICI term that does NOT scale
with sequence length.  We compress the wire format and carry the
quantization error forward as an *error-feedback residual* (Seide et al.
1-bit SGD; Karimireddy et al. EF-SGD): the residual is added to the next
step's gradients before compression, so the quantization noise is unbiased
over time and the compressed loss curve tracks the uncompressed one.

Methods (``OptimizerConfig.grad_compression``):
  none      — identity.
  bf16      — cast to bfloat16 on the wire (2x), residual = rounding error.
  int8_ef   — per-tensor absmax int8 quantization (4x), error feedback.
  topk_ef   — keep the top ``TOPK_FRACTION`` entries by magnitude exactly
              (sparsification), error feedback carries the rest.

The wire format is a dict of parallel pytrees (each mirroring the gradient
tree), so it passes through jit/scan untouched.  ``decompress_grads`` needs
the original gradient tree (or shapes) to rebuild dense leaves.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any

TOPK_FRACTION = 0.05

METHODS = ("none", "bf16", "int8_ef", "topk_ef")

# wire bytes per gradient element (f32 baseline is 4)
WIRE_BYTES_PER_ELEM = {
    "none": 4.0,
    "bf16": 2.0,
    "int8_ef": 1.0,
    "topk_ef": TOPK_FRACTION * 8.0,     # (int32 index + f32 value) per kept
}


def wire_bytes_per_elem(method: str, grad_dtype_bytes: float = 4.0) -> float:
    """Per-element wire width for ``method``, given the *native* gradient
    dtype width. Only "none" ships the native dtype (bf16 grads -> 2 bytes
    uncompressed); the other methods fix their own wire format regardless
    of what the gradients started as."""
    _check(method)
    if method == "none":
        return float(grad_dtype_bytes)
    return WIRE_BYTES_PER_ELEM[method]


def uses_error_feedback(method: str) -> bool:
    return method.endswith("_ef")


def _check(method: str) -> None:
    if method not in METHODS:
        raise ValueError(f"unknown grad compression {method!r}; "
                         f"one of {METHODS}")


def _topk_k(n: int) -> int:
    return max(1, int(math.ceil(TOPK_FRACTION * n)))


def compress_grads(grads: Params, method: str = "int8_ef"
                   ) -> Tuple[Dict[str, Params], Optional[Params]]:
    """Compress a gradient pytree to its wire format.

    Returns ``(wire, residual)`` where ``residual = grads -
    decompress(wire)`` is the error-feedback state to add to the *next*
    step's gradients (``None`` for method "none").  All ops are jit-safe.
    """
    _check(method)
    if method == "none":
        return {"q": grads}, None

    if method == "bf16":
        q = jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)
        err = jax.tree.map(
            lambda g, w: g.astype(jnp.float32) - w.astype(jnp.float32),
            grads, q)
        return {"q": q}, err

    if method == "int8_ef":
        scale = jax.tree.map(
            lambda g: jnp.maximum(
                jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0, 1e-30),
            grads)
        q = jax.tree.map(
            lambda g, s: jnp.clip(
                jnp.round(g.astype(jnp.float32) / s), -127, 127
            ).astype(jnp.int8), grads, scale)
        err = jax.tree.map(
            lambda g, qq, s: g.astype(jnp.float32)
            - qq.astype(jnp.float32) * s, grads, q, scale)
        return {"q": q, "scale": scale}, err

    # topk_ef
    idx = jax.tree.map(
        lambda g: jax.lax.top_k(
            jnp.abs(g.astype(jnp.float32).reshape(-1)),
            _topk_k(g.size))[1].astype(jnp.int32), grads)
    vals = jax.tree.map(
        lambda g, i: g.astype(jnp.float32).reshape(-1)[i], grads, idx)
    err = jax.tree.map(
        lambda g, i, v: g.astype(jnp.float32).reshape(-1).at[i].set(0.0)
        .reshape(g.shape), grads, idx, vals)
    return {"idx": idx, "vals": vals}, err


def decompress_grads(wire: Dict[str, Params], method: str,
                     like: Params) -> Params:
    """Rebuild a dense gradient pytree (dtype of ``like``) from the wire
    format produced by ``compress_grads``."""
    _check(method)
    if method == "none":
        return wire["q"]
    if method == "bf16":
        return jax.tree.map(lambda w, g: w.astype(g.dtype),
                            wire["q"], like)
    if method == "int8_ef":
        return jax.tree.map(
            lambda q, s, g: (q.astype(jnp.float32) * s).astype(g.dtype),
            wire["q"], wire["scale"], like)
    # topk_ef
    return jax.tree.map(
        lambda i, v, g: jnp.zeros(g.size, jnp.float32).at[i].set(v)
        .reshape(g.shape).astype(g.dtype), wire["idx"], wire["vals"], like)


def init_residual(params: Params, method: str) -> Optional[Params]:
    """Zero error-feedback state (same tree as ``params``, f32), or ``None``
    for methods without error feedback."""
    _check(method)
    if not uses_error_feedback(method):
        return None
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


# ---------------------------------------------------------------------------
# wire accounting (surfaced into EpochLog.stats by the trainer)


def dp_grad_wire_bytes(params: Params, method: str, dp_degree: int, *,
                       grad_dtype_bytes: float = 4.0,
                       micro_reduces: int = 1) -> float:
    """Per-step on-the-wire bytes of the DP gradient reduction under
    ``method`` compression on a ``dp_degree``-way ring (2*(n-1)/n per
    buffer byte). 0 when there is no data parallelism.

    ``grad_dtype_bytes`` is the native gradient width (2 for bf16 grads);
    it only matters for method "none" — see ``wire_bytes_per_elem``.
    ``micro_reduces`` is how many parameter-sized reductions one optimizer
    step issues: 1 for plain DP (grads accumulate locally, one all-reduce),
    ``run.microbatches`` under ZeRO-3, whose per-microbatch reduce-scatter
    cannot be deferred because no device holds the full gradient.
    """
    _check(method)
    if dp_degree <= 1:
        return 0.0
    n_elem = sum(int(l.size) for l in jax.tree.leaves(params))
    buf = n_elem * wire_bytes_per_elem(method, grad_dtype_bytes)
    reduces = max(1, int(micro_reduces))
    return float(2.0 * (dp_degree - 1) / dp_degree * buf * reduces)
