"""Logical -> physical sharding rules keyed on parameter path patterns.

Parameters are plain dict pytrees; the leaf *path* carries the semantics
(``repro.models.layers`` docstring): any leaf whose path ends in ``wq``/
``wi`` is a column-parallel kernel, ``wo``/``out_proj`` row-parallel, expert
kernels ``e_*`` shard over experts (EP) when the expert count divides the
model degree and fall back to feature-dim TP otherwise, and so on.  The rule
table below is the single place the megatron/FSDP layout lives; models and
launchers only consume the resulting ``PartitionSpec`` trees.

Layout summary (full table in ``repro/dist/README.md``):

  leaf suffix              spec (trailing dims)         condition
  ----------------------   --------------------------   -----------------------
  embed                    ("model", None)              vocab % tp == 0
  lm_head                  (None, "model")
  wq / wi / s_wg / ...     (..., "model")               column-parallel
  wk / wv / bk / bv        (..., "model")               num_kv_heads % tp == 0
  w_uq / w_uk / w_uv       (..., "model")               num_heads % tp == 0
  wo / out_proj / s_wo     (..., "model", None)         row-parallel
  e_wg / e_wu / e_wo       ("model" on expert dim)      E % tp == 0 (EP)
  e_wg / e_wu (TP fall.)   (..., "model")               feature dim
  e_* (moe_full_ep)        (dp x model on expert dim)   E % (dp*tp) == 0
  norms / biases / router  replicated

FSDP (ZeRO-style) additionally shards big layer kernels over the data axis
(and the pod axis with ``fsdp_over_pods``): any non-exempt leaf whose
per-TP-shard footprint exceeds ``FSDP_MIN_BYTES`` gets the data axes on its
largest still-unsharded divisible dim.  Embeddings, the LM head, and
position tables are exempt — they are already vocab-sharded over the model
axis and are touched once per step, so ZeRO gathers would cost more than
they save.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig
from repro.models.layers import pad_heads, padded_vocab

Params = Any

# Per-TP-shard bytes above which an FSDP-eligible leaf is data-sharded.
# Keyed on the *stored* dtype: at the production bf16 param dtype the layer
# kernels of every >3B assigned arch cross it while norm scales never do.
FSDP_MIN_BYTES = 2 ** 27  # 128 MiB

# Leaves never FSDP-sharded (see module docstring).
_FSDP_EXEMPT = ("embed", "lm_head", "enc_pos", "dec_pos")

# Leaf names sharded on the last (output/feature) dim over the model axis.
_COLUMN = ("wq", "wi", "bq", "bi", "s_wg", "s_wu", "in_proj", "conv_w",
           "conv_b", "dt_proj", "w_a2", "w_r", "w_g", "w_k")
# Leaf names sharded on dim -2 (input/feature) over the model axis.
_ROW = ("wo", "bo_row", "s_wo", "out_proj", "w_o", "w_v")
# KV projections: shard only when the kv-head count divides tp (otherwise a
# head would straddle shards; we replicate instead of splitting heads).
_KV = ("wk", "wv", "bk", "bv")
# MLA latent->per-head kernels: head-structured output dim.
_HEADED = ("w_uq", "w_uk", "w_uv")
# Expert kernels: (E, d, f) / (E, f, d) with a leading stacking dim.
_EXPERT_COL = ("e_wg", "e_wu")   # TP fallback shards f = last dim
_EXPERT_ROW = ("e_wo",)          # TP fallback shards f = dim -2


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)


def _axes_entry(axes: Sequence[str]):
    return axes[0] if len(axes) == 1 else tuple(axes)


def _dp_axes(mesh: MeshConfig, over_pods: bool) -> Tuple[str, ...]:
    want = ("pod", "data") if over_pods else ("data",)
    return tuple(a for a in mesh.axes if a in want)


def _degree(mesh: MeshConfig, axes: Sequence[str]) -> int:
    d = 1
    for s, a in zip(mesh.shape, mesh.axes):
        if a in axes:
            d *= s
    return d


def _base_entries(names: Tuple[str, ...], shape: Tuple[int, ...],
                  cfg: ModelConfig, tp: int, moe_full_ep: bool,
                  mesh: MeshConfig) -> list:
    """Model-axis (TP/EP) entries for one leaf; one entry per dim."""
    nd = len(shape)
    entries: list = [None] * nd
    if nd == 0:
        return entries
    name = names[-1]
    parent = names[-2] if len(names) > 1 else ""
    # RWKV name collision: time-mix w_k/w_v (under "mixer") are column
    # kernels; channel-mix w_k (column) / w_v (row) live under "ffn". The
    # class lists above encode the ffn variant; flip for the mixer.
    if parent == "mixer" and name in ("w_v",):
        cls_row, cls_col = False, True
    else:
        cls_col = name in _COLUMN
        cls_row = name in _ROW

    def put(dim_idx: int, axes: Sequence[str]) -> None:
        deg = _degree(mesh, axes)
        if axes and deg > 1 and shape[dim_idx] % deg == 0:
            entries[dim_idx] = _axes_entry(tuple(axes))

    if tp <= 1 and not moe_full_ep:
        return entries
    has_model = "model" in mesh.axes

    if name == "embed":
        # (vocab_p, d): vocab rows over model; padded_vocab is a multiple of
        # 128 so every power-of-two tp divides it.
        if has_model and nd >= 2:
            put(nd - 2, ("model",))
        return entries
    if name == "lm_head":
        if has_model:
            put(nd - 1, ("model",))
        return entries
    if name in ("enc_pos", "dec_pos", "router") or not has_model:
        return entries

    if name in _EXPERT_COL + _EXPERT_ROW and cfg.moe is not None:
        e = cfg.moe.num_experts
        ep_axes = tuple(a for a in mesh.axes if a in ("data", "model")) \
            if moe_full_ep else ("model",)
        ep_deg = _degree(mesh, ep_axes)
        if e % ep_deg == 0 and nd >= 3:
            put(nd - 3, ep_axes)               # expert-parallel
        elif name in _EXPERT_COL:
            put(nd - 1, ("model",))            # TP fallback: shard f
        else:
            put(nd - 2, ("model",))
        return entries

    if name in _KV:
        if cfg.num_kv_heads and cfg.num_kv_heads % tp == 0:
            put(nd - 1, ("model",))
        return entries
    if name in _HEADED:
        if cfg.num_heads and cfg.num_heads % tp == 0:
            put(nd - 1, ("model",))
        return entries
    if cls_col:
        put(nd - 1, ("model",))
        return entries
    if cls_row and nd >= 2:
        put(nd - 2, ("model",))
        return entries
    return entries


def _apply_fsdp(entries: list, names: Tuple[str, ...],
                shape: Tuple[int, ...], itemsize: int,
                mesh: MeshConfig, over_pods: bool) -> list:
    if names[-1] in _FSDP_EXEMPT:
        return entries
    dp = _dp_axes(mesh, over_pods)
    dp_deg = _degree(mesh, dp)
    if not dp or dp_deg <= 1:
        return entries
    # per-TP-shard footprint: total bytes / extent already sharded away
    sharded = 1
    for e, s in zip(entries, shape):
        if e is not None:
            sharded *= _degree(mesh, (e,) if isinstance(e, str) else e)
    size = itemsize
    for s in shape:
        size *= s
    if size // max(sharded, 1) < FSDP_MIN_BYTES:
        return entries
    # largest still-unsharded dim divisible by the dp degree
    cands = sorted((s, i) for i, (e, s) in enumerate(zip(entries, shape))
                   if e is None and s % dp_deg == 0)
    if cands:
        entries[cands[-1][1]] = _axes_entry(dp)
    return entries


def param_specs(params: Params, cfg: ModelConfig, mesh: MeshConfig,
                fsdp: bool = False, fsdp_over_pods: bool = False,
                moe_full_ep: bool = False,
                parallelism: str = "tp") -> Params:
    """PyTree of ``PartitionSpec`` matching ``params`` (shapes or arrays).

    ``parallelism="dp_only"`` replicates every parameter (the whole mesh is
    the batch); FSDP may still storage-shard big kernels over the data axes.
    """
    tp = mesh.model_degree if parallelism == "tp" else 1

    def one(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        itemsize = jax.numpy.dtype(leaf.dtype).itemsize
        entries = _base_entries(names, shape, cfg, tp, moe_full_ep, mesh)
        if fsdp:
            entries = _apply_fsdp(entries, names, shape, itemsize, mesh,
                                  fsdp_over_pods)
        return P(*entries)

    return jax.tree_util.tree_map_with_path(one, params)


def batch_specs(batch: Params, mesh: MeshConfig, shape: ShapeConfig,
                parallelism: str = "tp") -> Params:
    """Batch inputs shard dim 0 over the data axes (the whole mesh under
    ``dp_only``) when the global batch divides; otherwise replicate."""
    if parallelism == "dp_only":
        dp = mesh.axes
    else:
        dp = tuple(a for a in mesh.axes if a in ("pod", "data"))
    deg = _degree(mesh, dp)

    def one(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        if deg > 1 and leaf.shape[0] % deg == 0:
            return P(_axes_entry(dp), *([None] * (nd - 1)))
        return P(*([None] * nd))

    return jax.tree.map(one, batch)


def cache_specs(cache: Params, cfg: ModelConfig, mesh: MeshConfig,
                shape: ShapeConfig) -> Params:
    """Decode caches: batch (dim 1, after the layer-stacking dim) over the
    data axes; attention KV head dims over the model axis when head-aligned.
    Conservative for state caches (mamba/rwkv): batch sharding only."""
    dp = tuple(a for a in mesh.axes if a in ("pod", "data"))
    dp_deg = _degree(mesh, dp)
    tp = mesh.model_degree
    head_sizes = set()
    if cfg.num_kv_heads:
        head_sizes.add(cfg.num_kv_heads)
        head_sizes.add(pad_heads(cfg.num_kv_heads, tp))
    if cfg.num_heads:
        head_sizes.add(pad_heads(cfg.num_heads, tp))

    def one(leaf):
        nd = len(leaf.shape)
        entries: list = [None] * nd
        if nd >= 2 and leaf.shape[1] == shape.global_batch \
                and dp_deg > 1 and leaf.shape[1] % dp_deg == 0:
            entries[1] = _axes_entry(dp)
        if nd == 5 and tp > 1 and leaf.shape[-2] in head_sizes \
                and leaf.shape[-2] % tp == 0:
            entries[-2] = "model"
        return P(*entries)

    return jax.tree.map(one, cache)


# ---------------------------------------------------------------------------
# analytic collective accounting (per-SL communication projection)


def tp_activation_wire_bytes(cfg: ModelConfig, global_batch: int,
                             seq_len: int, tp: int, *,
                             dtype_bytes: int = 2,
                             training: bool = True) -> float:
    """Per-step on-the-wire bytes of the TP activation all-reduces.

    Megatron layout: 2 all-reduces of the (B, S, d) residual per block
    (attention output + FFN output), each ring all-reduce moving
    ``2*(tp-1)/tp`` bytes per buffer byte; backward doubles them. This is
    the SL-proportional communication term SeqPoint projects (ISSUE 6 /
    Daydream's "model the comms or mispredict the optimization").
    """
    if tp <= 1:
        return 0.0
    buf = global_batch * seq_len * cfg.d_model * dtype_bytes
    per_block = 2 * buf * 2.0 * (tp - 1) / tp
    total = per_block * cfg.num_layers
    if training:
        total *= 2.0
    return float(total)


def dp_grad_reduce_elems(params: Params, specs: Params,
                         mesh: MeshConfig) -> float:
    """Per-device gradient elements participating in the DP reduction.

    The DP gradient reduce spans the data axes, so each device's buffer is
    its leaf shard over the *non-data* mesh axes only: a TP-sharded kernel
    contributes ``size/tp``, a replicated leaf (most attention-free mixers)
    contributes its full size. This is the exact quantity the analytic
    ``dp_grad`` wire term should price per device — ``param_count`` alone
    cannot distinguish the two cases, which differ by the whole model
    degree.
    """
    extent = dict(zip(mesh.axes, mesh.shape))
    data_axes = {"pod", "data"}
    total = 0.0
    spec_leaves = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    for leaf, spec in zip(jax.tree.leaves(params), spec_leaves):
        shards = 1
        for entry in spec:
            names = () if entry is None else (
                (entry,) if isinstance(entry, str) else tuple(entry))
            for name in names:
                if name not in data_axes:
                    shards *= extent.get(name, 1)
        size = 1
        for dim in leaf.shape:
            size *= int(dim)
        total += size / max(shards, 1)
    return float(total)
