"""repro.dist — named logical axes, sharding rules, grad compression.

See ``src/repro/dist/README.md`` for the layout tables and the tier-1
verification command.
"""
from repro.dist.axes import (
    active_mesh,
    constrain,
    current_mesh_axes,
    dp_axes,
    set_dp_axes,
    _resolve,
)
from repro.dist.compression import (
    METHODS,
    WIRE_BYTES_PER_ELEM,
    compress_grads,
    decompress_grads,
    dp_grad_wire_bytes,
    init_residual,
    uses_error_feedback,
    wire_bytes_per_elem,
)
from repro.dist.sharding import (
    FSDP_MIN_BYTES,
    batch_specs,
    cache_specs,
    dp_grad_reduce_elems,
    param_specs,
    tp_activation_wire_bytes,
)

__all__ = [
    "active_mesh",
    "constrain",
    "current_mesh_axes",
    "dp_axes",
    "set_dp_axes",
    "_resolve",
    "METHODS",
    "WIRE_BYTES_PER_ELEM",
    "compress_grads",
    "decompress_grads",
    "dp_grad_wire_bytes",
    "init_residual",
    "uses_error_feedback",
    "wire_bytes_per_elem",
    "FSDP_MIN_BYTES",
    "batch_specs",
    "cache_specs",
    "param_specs",
    "dp_grad_reduce_elems",
    "tp_activation_wire_bytes",
]
