"""repro.obs — observability layer: span tracing, SL-keyed metrics,
structured events, and SeqPoint projection-error monitoring.

Hot paths use the module-level helpers unconditionally; everything is a
no-op until ``enable()`` installs a tracer/event sink (or the
``REPRO_OBS_DIR`` environment variable does at process start). See
``src/repro/obs/README.md`` for the span taxonomy and metric names.

    from repro import obs

    obs.enable(out_dir="results/obs")
    with obs.span("train/step", sl=128):
        ...
    obs.metrics.histogram("train_step_time_s", sl=128).observe(dt)
    obs.event("straggler", step=7, sl=128, dt=0.9)
    obs.export_all()        # trace.json + metrics.json/.prom + events flush
"""
from __future__ import annotations

import atexit
import os
from typing import Any, Dict, Optional

from repro.obs.events import (
    DEFAULT_EVENTS_PATH,
    EventSink,
    event,
    get_sink,
    set_sink,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    MetricsServer,
    bucket_bound,
    get_registry,
    metrics,
    serve_http,
)
from repro.obs.projection import (
    ProjectionMonitor,
    ProjectionReport,
    SLResidual,
    analytic_wire_bytes,
    cell_collective_projection,
    collective_projection_report,
)
from repro.obs.trace import (
    NULL_SPAN,
    Tracer,
    enable_tracing,
    get_tracer,
    set_tracer,
    span,
    traced,
    tracing_enabled,
)

__all__ = [
    "Counter", "DEFAULT_EVENTS_PATH", "EventSink", "Gauge", "Histogram",
    "MetricsRegistry", "MetricsServer", "NULL_SPAN", "ProjectionMonitor",
    "ProjectionReport", "SLResidual", "Tracer", "analytic_wire_bytes",
    "cell_collective_projection", "collective_projection_report",
    "bucket_bound", "disable", "enable", "enable_tracing", "event",
    "export_all", "get_registry", "get_sink", "get_tracer", "metrics",
    "serve_http", "set_sink", "set_tracer", "span", "traced",
    "tracing_enabled",
]

_OUT_DIR: Optional[str] = None
_ATEXIT_REGISTERED = False


def _export_at_exit() -> None:
    if _OUT_DIR is not None and tracing_enabled():
        try:
            export_all()
        except Exception:       # noqa: BLE001 — never fail the interpreter
            pass


def enable(*, trace: bool = True, out_dir: Optional[str] = None,
           events_path: Optional[str] = None,
           flush_every: int = 32) -> None:
    """Turn the layer on: tracing + a JSONL event sink.

    ``out_dir`` anchors ``export_all()`` and defaults the events path to
    ``<out_dir>/events.jsonl``; without it events go to the repo-level
    ``results/events.jsonl``. With an ``out_dir``, artifacts also export
    automatically at interpreter exit, so ``REPRO_OBS_DIR`` works for any
    entrypoint without an explicit ``export_all()`` call.
    """
    global _OUT_DIR, _ATEXIT_REGISTERED
    _OUT_DIR = out_dir
    enable_tracing(trace)
    if events_path is None and out_dir is not None:
        events_path = os.path.join(out_dir, "events.jsonl")
    prev = set_sink(EventSink(events_path, flush_every=flush_every))
    if prev is not None:
        prev.close()
    if out_dir is not None and not _ATEXIT_REGISTERED:
        atexit.register(_export_at_exit)
        _ATEXIT_REGISTERED = True


def disable() -> None:
    """Back to zero-cost: tracing off, event sink closed and removed."""
    enable_tracing(False)
    prev = set_sink(None)
    if prev is not None:
        prev.close()


def export_all(out_dir: Optional[str] = None) -> Dict[str, str]:
    """Write trace.json (Chrome/Perfetto), metrics.json, metrics.prom and
    flush the event sink; returns the paths written."""
    out_dir = out_dir or _OUT_DIR or os.path.dirname(DEFAULT_EVENTS_PATH)
    os.makedirs(out_dir, exist_ok=True)
    paths: Dict[str, str] = {}
    paths["trace"] = get_tracer().export_chrome_trace(
        os.path.join(out_dir, "trace.json"))
    mpath = os.path.join(out_dir, "metrics.json")
    with open(mpath, "w") as f:
        f.write(metrics.to_json(indent=1))
    paths["metrics_json"] = mpath
    ppath = os.path.join(out_dir, "metrics.prom")
    with open(ppath, "w") as f:
        f.write(metrics.to_prometheus())
    paths["metrics_prom"] = ppath
    sink = get_sink()
    if sink is not None:
        sink.flush()
        paths["events"] = sink.path
    return paths


# opt-in via environment: REPRO_OBS_DIR=<dir> enables tracing + events for
# any entrypoint without code changes (CI uses this for quickstart).
_env_dir = os.environ.get("REPRO_OBS_DIR")
if _env_dir:
    enable(out_dir=_env_dir)
