"""Process-wide metrics registry: counters, gauges, log2-bucketed histograms.

Everything SeqPoint cares about is keyed by sequence length, so metrics take
free-form label kwargs (``histogram("train_step_time_s", sl=128)``) and the
histogram buckets are powers of two — the same log-scale geometry as padded
SLs themselves. A value ``v`` lands in the bucket whose upper bound is the
smallest power of two ``>= v`` (exact powers of two land on their own
bound), so bucket edges are stable across runs and merges are trivial.

Export: ``snapshot()`` (plain dicts, JSON-ready) and ``to_prometheus()``
(text exposition format with cumulative ``_bucket{le=...}`` lines).
Mutation ops are single dict/float updates under the GIL; registry creation
is locked.
"""
from __future__ import annotations

import json
import math
import re
import threading
from typing import Any, Dict, List, Optional, Tuple

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, n: float = 1.0) -> None:
        self.value += n


def bucket_bound(v: float) -> float:
    """Smallest power of two >= v (the bucket's ``le`` bound); 0 for v<=0."""
    if v <= 0.0:
        return 0.0
    return float(2.0 ** math.ceil(math.log2(v)))


class Histogram:
    """Sparse log2-bucketed histogram with sum/count/min/max."""

    __slots__ = ("buckets", "count", "sum", "min", "max")

    def __init__(self) -> None:
        self.buckets: Dict[float, int] = {}
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        b = bucket_bound(v)
        self.buckets[b] = self.buckets.get(b, 0) + 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def cumulative(self) -> List[Tuple[float, int]]:
        """(le, cumulative count) pairs in ascending bound order."""
        out, acc = [], 0
        for b in sorted(self.buckets):
            acc += self.buckets[b]
            out.append((b, acc))
        return out


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        # name -> (type_name, {label_key: metric})
        self._metrics: Dict[str, Tuple[str, Dict[LabelKey, Any]]] = {}

    def _get(self, type_name: str, name: str, labels: Dict[str, Any]):
        key = _label_key(labels)
        entry = self._metrics.get(name)
        if entry is not None and key in entry[1]:
            if entry[0] != type_name:
                raise TypeError(f"metric {name!r} is a {entry[0]}, "
                                f"not a {type_name}")
            return entry[1][key]
        with self._lock:
            entry = self._metrics.setdefault(name, (type_name, {}))
            if entry[0] != type_name:
                raise TypeError(f"metric {name!r} is a {entry[0]}, "
                                f"not a {type_name}")
            return entry[1].setdefault(key, _TYPES[type_name]())

    def counter(self, name: str, **labels: Any) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels: Any) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, **labels: Any) -> Histogram:
        return self._get("histogram", name, labels)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    # -- export ---------------------------------------------------------
    def snapshot(self) -> Dict[str, List[Dict[str, Any]]]:
        """JSON-ready view: name -> list of {type, labels, ...} series."""
        out: Dict[str, List[Dict[str, Any]]] = {}
        with self._lock:
            items = {n: (t, dict(series))
                     for n, (t, series) in self._metrics.items()}
        for name, (type_name, series) in sorted(items.items()):
            rows = []
            for key, m in sorted(series.items()):
                row: Dict[str, Any] = {"type": type_name,
                                       "labels": dict(key)}
                if type_name in ("counter", "gauge"):
                    row["value"] = m.value
                else:
                    row.update(count=m.count, sum=m.sum, mean=m.mean,
                               min=m.min if m.count else None,
                               max=m.max if m.count else None,
                               buckets={str(b): c for b, c
                                        in sorted(m.buckets.items())})
                rows.append(row)
            out[name] = rows
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.snapshot(), indent=indent)

    def to_prometheus(self) -> str:
        lines: List[str] = []
        snap_src: Dict[str, Tuple[str, Dict[LabelKey, Any]]]
        with self._lock:
            snap_src = {n: (t, dict(series))
                        for n, (t, series) in self._metrics.items()}
        for name, (type_name, series) in sorted(snap_src.items()):
            pname = _prom_name(name)
            lines.append(f"# TYPE {pname} {type_name}")
            for key, m in sorted(series.items()):
                lbl = _prom_labels(key)
                if type_name in ("counter", "gauge"):
                    lines.append(f"{pname}{lbl} {_fmt(m.value)}")
                    continue
                for le, cum in m.cumulative():
                    lines.append(f"{pname}_bucket"
                                 f"{_prom_labels(key, le=_fmt(le))} {cum}")
                lines.append(f"{pname}_bucket"
                             f"{_prom_labels(key, le='+Inf')} {m.count}")
                lines.append(f"{pname}_sum{lbl} {_fmt(m.sum)}")
                lines.append(f"{pname}_count{lbl} {m.count}")
        return "\n".join(lines) + "\n"


def _prom_name(name: str) -> str:
    return re.sub(r"[^a-zA-Z0-9_:]", "_", name)


def _fmt(v: float) -> str:
    return repr(int(v)) if float(v).is_integer() and abs(v) < 1e15 \
        else repr(float(v))


def _prom_labels(key: LabelKey, **extra: str) -> str:
    pairs = list(key) + sorted(extra.items())
    if not pairs:
        return ""
    body = ",".join(f'{_prom_name(k)}="{v}"' for k, v in pairs)
    return "{" + body + "}"


# --------------------------------------------------------------------------
# process-global registry

metrics = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return metrics


# --------------------------------------------------------------------------
# live Prometheus scrape endpoint (closes the snapshot-at-exit gap: metrics
# were only visible after the run via export_all; a scraper can now watch a
# training or serving run in flight)


class MetricsServer:
    """Handle for a running scrape endpoint: ``.port``, ``.url``,
    ``.close()``. Context-manager friendly."""

    def __init__(self, httpd, thread):
        self._httpd = httpd
        self._thread = thread
        self.addr, self.port = httpd.server_address[:2]

    @property
    def url(self) -> str:
        return f"http://{self.addr}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def serve_http(port: int = 0, addr: str = "127.0.0.1",
               registry: Optional[MetricsRegistry] = None) -> MetricsServer:
    """Start a background-thread HTTP server exposing the registry in
    Prometheus text format at ``/metrics`` (and ``/`` as a pointer).

    Stdlib-only (``http.server``); every scrape renders a fresh
    ``to_prometheus()`` so the numbers are live, not snapshot-at-exit.
    ``port=0`` binds an ephemeral port (see the returned handle's
    ``.port``). The serving thread is a daemon: it never blocks
    interpreter exit, but call ``.close()`` for a clean shutdown.
    """
    import http.server

    reg = registry or metrics

    class Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):                            # noqa: N802 (stdlib)
            if self.path.rstrip("/") in ("", "/index.html"):
                body = b"repro.obs metrics: scrape /metrics\n"
                ctype = "text/plain; charset=utf-8"
            elif self.path.startswith("/metrics"):
                body = reg.to_prometheus().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):                # keep scrapes silent
            pass

    httpd = http.server.ThreadingHTTPServer((addr, port), Handler)
    httpd.daemon_threads = True
    t = threading.Thread(target=httpd.serve_forever,
                         name="repro-obs-metrics-http", daemon=True)
    t.start()
    return MetricsServer(httpd, t)
