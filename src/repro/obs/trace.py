"""Lightweight span tracer with Chrome-trace-event export.

SeqPoint's premise is that detailed profiling is too expensive to run on
every iteration (paper §I) — so the tracer must cost nothing when it is off
and almost nothing when it is on. Disabled, ``span()`` returns one shared
no-op context manager: no clock read, no allocation, no lock. Enabled, each
span is a single perf_counter pair plus one dict appended under a lock.

Spans nest via a thread-local stack, so concurrent threads (e.g. the async
checkpoint writer) interleave correctly in the exported trace. Export is the
Chrome trace-event JSON format (``{"traceEvents": [...]}``, "X" complete
events with microsecond timestamps) — drop the file into Perfetto
(https://ui.perfetto.dev) or chrome://tracing and the nesting renders as a
flame graph per thread.
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional


class _NullSpan:
    """Shared do-nothing span handed out while tracing is disabled."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: object) -> bool:
        return False

    def set(self, **args: Any) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("tracer", "name", "args", "t0", "depth")

    def __init__(self, tracer: "Tracer", name: str, args: Dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.depth = 0

    def set(self, **args: Any) -> "_Span":
        """Attach attributes after entry (e.g. a result computed inside)."""
        self.args.update(args)
        return self

    def __enter__(self) -> "_Span":
        stack = self.tracer._stack()
        self.depth = len(stack)
        stack.append(self.name)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: object) -> bool:
        t1 = time.perf_counter()
        self.tracer._stack().pop()
        self.tracer._record(self, t1)
        return False


class Tracer:
    """Collects spans as Chrome trace events; thread-safe."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self.events: List[Dict[str, Any]] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._epoch = time.perf_counter()

    # -- recording ------------------------------------------------------
    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _record(self, sp: _Span, t1: float) -> None:
        ev = {
            "name": sp.name,
            "ph": "X",
            "ts": (sp.t0 - self._epoch) * 1e6,      # microseconds
            "dur": (t1 - sp.t0) * 1e6,
            "pid": os.getpid(),
            "tid": threading.get_ident(),
        }
        if sp.args or sp.depth:
            ev["args"] = dict(sp.args, depth=sp.depth)
        with self._lock:
            self.events.append(ev)

    def span(self, name: str, **args: Any):
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, args)

    def current_span(self) -> Optional[str]:
        stack = self._stack()
        return stack[-1] if stack else None

    def clear(self) -> None:
        with self._lock:
            self.events.clear()

    # -- export ---------------------------------------------------------
    def to_chrome_trace(self) -> Dict[str, Any]:
        with self._lock:
            events = list(self.events)
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


# --------------------------------------------------------------------------
# process-global tracer (disabled by default: zero-cost in production paths)

_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the global tracer (tests); returns the previous one."""
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


def enable_tracing(on: bool = True) -> None:
    _TRACER.enabled = on


def tracing_enabled() -> bool:
    return _TRACER.enabled


def span(name: str, **args: Any):
    """``with span("train/step", sl=128): ...`` on the global tracer."""
    tracer = _TRACER
    if not tracer.enabled:
        return NULL_SPAN
    return _Span(tracer, name, args)


def traced(name: Optional[str] = None) -> Callable:
    """Decorator form: ``@traced()`` wraps the call in a span."""

    def deco(fn: Callable) -> Callable:
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a: Any, **kw: Any):
            with span(label):
                return fn(*a, **kw)

        return wrapper

    return deco
