"""SeqPoint projection-error monitoring: check the projections against the
ground truth they claim to predict.

Daydream (2020)'s lesson is that an optimization-efficacy estimate is only
trustworthy once validated against instrumented execution. Two validators
live here:

* ``ProjectionMonitor`` — given a ``SeqPointSet`` selected earlier, watch a
  live ``EpochLog`` (or a stream of ``observe(sl, runtime)`` calls) and
  report the running projected-vs-measured epoch runtime plus per-SL
  residuals. Each observed iteration is predicted by its nearest SeqPoint's
  profiled runtime — exactly the substitution Eq. 1 makes, now checked
  online instead of assumed.

* ``cell_collective_projection`` / ``collective_projection_report`` — the
  analytic communication model (``tp_activation_wire_bytes`` +
  ``dp_grad_wire_bytes``) against *measured* HLO collective bytes from
  ``perfmodel.hlo.parse_collectives``, per dry-run cell (ROADMAP open
  item). The residual between the two is the model's blind spot (e.g. ZeRO
  param gathers), reported per collective kind so it is attributable.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, StepKind
from repro.core.profile import EpochLog
from repro.core.seqpoint import SeqPointSet
from repro.dist.compression import wire_bytes_per_elem
from repro.dist.sharding import tp_activation_wire_bytes
from repro.perfmodel.hlo import CollectiveStats
from repro.perfmodel.model_flops import param_count


# --------------------------------------------------------------------------
# live epoch-runtime projection


@dataclass(frozen=True)
class SLResidual:
    seq_len: int
    iterations: int
    measured_mean: float       # mean measured per-iteration runtime
    predicted: float           # nearest-SeqPoint profiled runtime
    residual: float            # measured_mean - predicted
    rel_error: float


@dataclass
class ProjectionReport:
    iterations: int
    measured_total: float      # sum of observed runtimes
    projected_total: float     # same iterations priced by their SeqPoints
    rel_error: float           # |projected - measured| / measured
    eq1_predicted: float       # full-epoch Eq. 1 number from selection time
    per_sl: List[SLResidual] = field(default_factory=list)

    def worst_sl(self) -> Optional[SLResidual]:
        if not self.per_sl:
            return None
        return max(self.per_sl, key=lambda r: abs(r.rel_error))


class ProjectionMonitor:
    """Running projected-vs-measured check for one ``SeqPointSet``."""

    def __init__(self, seqpoints: SeqPointSet):
        if not seqpoints.points:
            raise ValueError("SeqPointSet has no points")
        self.seqpoints = seqpoints
        pts = sorted(seqpoints.points, key=lambda p: p.seq_len)
        self._sp_sls = np.array([p.seq_len for p in pts], dtype=np.int64)
        self._sp_rts = np.array([p.runtime for p in pts])
        # per observed SL: [count, measured_sum]
        self._by_sl: Dict[int, List[float]] = {}
        self.measured_total = 0.0
        self.projected_total = 0.0
        self.iterations = 0

    def predict(self, sl: int) -> float:
        """Per-iteration runtime the projection assigns to ``sl``: the
        profiled runtime of the nearest SeqPoint (its bin representative)."""
        idx = int(np.argmin(np.abs(self._sp_sls - int(sl))))
        return float(self._sp_rts[idx])

    def observe(self, sl: int, runtime: float) -> None:
        sl = int(sl)
        acc = self._by_sl.setdefault(sl, [0.0, 0.0])
        acc[0] += 1
        acc[1] += runtime
        self.measured_total += runtime
        self.projected_total += self.predict(sl)
        self.iterations += 1

    def observe_log(self, log: EpochLog) -> None:
        for it in log.iterations:
            self.observe(it.seq_len, it.runtime)

    def report(self) -> ProjectionReport:
        per_sl = []
        for sl in sorted(self._by_sl):
            n, total = self._by_sl[sl]
            mean = total / n
            pred = self.predict(sl)
            per_sl.append(SLResidual(
                seq_len=sl, iterations=int(n), measured_mean=mean,
                predicted=pred, residual=mean - pred,
                rel_error=(mean - pred) / max(mean, 1e-12)))
        return ProjectionReport(
            iterations=self.iterations,
            measured_total=self.measured_total,
            projected_total=self.projected_total,
            rel_error=abs(self.projected_total - self.measured_total)
            / max(self.measured_total, 1e-12),
            eq1_predicted=self.seqpoints.predicted,
            per_sl=per_sl)


# --------------------------------------------------------------------------
# analytic-vs-measured collective bytes (per dry-run cell)


def analytic_wire_bytes(cfg: ModelConfig, shape: ShapeConfig, *,
                        parallelism: str, dp_degree: int, tp_degree: int,
                        grad_compression: str = "none",
                        grad_dtype_bytes: float = 4.0,
                        micro_reduces: int = 1,
                        dp_reduce_elems: Optional[float] = None
                        ) -> Dict[str, float]:
    """The two analytic per-step communication terms SeqPoint projects.

    ``grad_dtype_bytes`` is the native gradient width (2 for bf16 compute,
    relevant only when ``grad_compression`` is "none"); ``micro_reduces``
    is the parameter-sized reductions per optimizer step (1 for plain DP,
    the microbatch count under ZeRO-3, where each microbatch's
    reduce-scatter goes on the wire immediately). ``dp_reduce_elems`` is
    the per-device gradient element count actually on the DP ring
    (``dist.sharding.dp_grad_reduce_elems`` from the real spec tree);
    without it the full parameter count is assumed, which overstates the
    term by the model degree when grads are TP-sharded.
    """
    training = shape.step == StepKind.TRAIN
    dp = 0.0
    if training and dp_degree > 1:
        elems = param_count(cfg, active=False) \
            if dp_reduce_elems is None else dp_reduce_elems
        buf = elems * wire_bytes_per_elem(grad_compression,
                                          grad_dtype_bytes)
        dp = 2.0 * (dp_degree - 1) / dp_degree * buf \
            * max(1, int(micro_reduces))
    # decode moves one token through the stack, not shape.seq_len
    sl = 1 if shape.step == StepKind.DECODE else shape.seq_len
    tp = tp_activation_wire_bytes(cfg, shape.global_batch, sl, tp_degree,
                                  training=training)
    return {"dp_grad": dp, "tp_activation": tp, "total": dp + tp}


# kinds the analytic model claims to cover: gradient all-reduce (or its
# ZeRO reduce-scatter + all-gather decomposition) + TP activation all-reduce
_REDUCE_KINDS = ("all-reduce", "reduce-scatter", "all-gather")
# kinds the analytic terms actually price: both the DP grad reduce and the
# TP activation reduce lower to all-reduces. ZeRO param all-gathers and
# halo collective-permutes are measured and attributed per kind but are
# deliberately outside the model — ``rel_error_claimed`` is the residual
# on the claimed kinds only, and is what the dryrun summary gates on.
_CLAIMED_KINDS = ("all-reduce",)


def cell_collective_projection(cfg: ModelConfig, shape: ShapeConfig,
                               run: RunConfig,
                               measured: CollectiveStats, *,
                               layers_counted: Optional[int] = None,
                               micro_counted: Optional[int] = None,
                               dp_reduce_elems: Optional[float] = None
                               ) -> Dict[str, Any]:
    """Analytic-vs-measured wire bytes for one dry-run cell.

    ``parse_collectives`` sums the per-device SPMD module, so the analytic
    terms are normalized to per-device: the TP activation number divides by
    the data degree (the residual is batch-sharded over ``dp``); the DP
    gradient number already is per-device ring traffic. ``layers_counted``
    handles compile-mode rolled scans, where the HLO text contains one scan
    body (one interleave period) rather than the full depth — pass
    ``cfg.interleave_period`` there, leave None for extrapolated
    (roofline) stats that already cover every layer. ``micro_counted`` is
    the same normalization for the microbatch scan: the number of
    microbatch bodies present in the measured HLO (1 for a rolled
    compile-mode scan; None when the stats cover every microbatch).
    ``dp_reduce_elems`` is forwarded to ``analytic_wire_bytes``.
    """
    dp_degree = (run.mesh.num_devices if run.parallelism == "dp_only"
                 else run.mesh.data_degree)
    tp_degree = run.mesh.model_degree if run.parallelism == "tp" else 1
    # bf16 compute keeps bf16 grads on the wire when uncompressed; ZeRO-3
    # reduce-scatters every microbatch (no local accumulation possible)
    grad_dtype_bytes = 2.0 if run.compute_dtype == "bfloat16" else 4.0
    micro_reduces = run.microbatches \
        if (run.fsdp and run.zero_stage >= 3) else 1
    micro_in_measurement = micro_reduces if micro_counted is None \
        else min(micro_reduces, int(micro_counted))
    analytic = analytic_wire_bytes(
        cfg, shape, parallelism=run.parallelism, dp_degree=dp_degree,
        tp_degree=tp_degree,
        grad_compression=run.optimizer.grad_compression,
        grad_dtype_bytes=grad_dtype_bytes,
        micro_reduces=micro_in_measurement,
        dp_reduce_elems=dp_reduce_elems)
    depth_frac = 1.0 if layers_counted is None \
        else layers_counted / max(cfg.num_layers, 1)
    a_tp = analytic["tp_activation"] / max(dp_degree, 1) * depth_frac
    a_dp = analytic["dp_grad"]
    a_total = a_dp + a_tp
    measured_total = float(measured.wire_bytes)
    measured_reduce = float(measured.wire_bytes_of(_REDUCE_KINDS))
    measured_claimed = float(measured.wire_bytes_of(_CLAIMED_KINDS))
    return {
        "analytic_dp_bytes": a_dp,
        "analytic_tp_bytes": a_tp,
        "analytic_wire_bytes": a_total,
        "layers_counted": layers_counted or cfg.num_layers,
        "measured_wire_bytes": measured_total,
        "measured_reduce_wire_bytes": measured_reduce,
        "measured_by_kind": measured.to_dict(),
        "rel_error": abs(a_total - measured_total)
        / max(measured_total, 1.0)
        if (a_total or measured_total) else 0.0,
        "rel_error_reduce": abs(a_total - measured_reduce)
        / max(measured_reduce, 1.0)
        if (a_total or measured_reduce) else 0.0,
        "measured_claimed_wire_bytes": measured_claimed,
        "rel_error_claimed": abs(a_total - measured_claimed)
        / max(measured_claimed, 1.0)
        if (a_total or measured_claimed) else 0.0,
        "dp_degree": dp_degree,
        "tp_degree": tp_degree,
        "grad_dtype_bytes": grad_dtype_bytes,
        "micro_reduces": micro_reduces,
        "micro_counted": micro_in_measurement,
        "dp_reduce_elems": dp_reduce_elems,
    }


def collective_projection_report(records: Iterable[Dict[str, Any]], *,
                                 error_bound: Optional[float] = None
                                 ) -> Dict[str, Any]:
    """Aggregate per-cell ``projection`` entries from dry-run records.

    Returns ``{"cells": [...], "max_rel_error": x, "within_bound": bool}``;
    ``within_bound`` is True when no cell exceeds ``error_bound`` (always
    True when no bound is given).
    """
    cells: List[Dict[str, Any]] = []
    for rec in records:
        proj = rec.get("projection")
        if proj is None or rec.get("status") not in (None, "ok"):
            continue
        cells.append({
            "cell": f"{rec.get('arch')}/{rec.get('shape')}"
                    f"@{rec.get('mesh', '?')}",
            **proj,
        })
    max_err = max((c["rel_error"] for c in cells), default=0.0)
    # the bound applies to the claimed-kind residual (all-reduces), the
    # number the analytic model is accountable for
    max_claimed = max(
        (c.get("rel_error_claimed", c["rel_error"]) for c in cells),
        default=0.0)
    return {
        "cells": cells,
        "num_cells": len(cells),
        "max_rel_error": max_err,
        "max_rel_error_claimed": max_claimed,
        "error_bound": error_bound,
        "within_bound": error_bound is None or max_claimed <= error_bound,
    }
