"""Structured JSONL event sink: run metadata, per-step records, stragglers,
checkpoint saves.

One event = one JSON object on one line, stamped with wallclock time and a
monotonically increasing sequence number, so downstream tooling (DeepProf
2017-style trace mining, or plain jq) can join events against the span
trace. Events buffer in memory and flush every ``flush_every`` emits (and on
``close``/interpreter exit); ``max_bytes`` rotates the file to ``<path>.1``
so long runs cannot fill a disk.

When no sink is installed the module-level ``event(...)`` is a single
``is None`` check — hot paths can emit unconditionally.
"""
from __future__ import annotations

import atexit
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional

_REPO_ROOT = os.path.join(os.path.dirname(__file__), "..", "..", "..")
DEFAULT_EVENTS_PATH = os.path.abspath(
    os.path.join(_REPO_ROOT, "results", "events.jsonl"))


class EventSink:
    def __init__(self, path: Optional[str] = None, *,
                 flush_every: int = 32,
                 max_bytes: Optional[int] = None):
        self.path = os.path.abspath(path or DEFAULT_EVENTS_PATH)
        self.flush_every = max(1, int(flush_every))
        self.max_bytes = max_bytes
        self._buf: List[str] = []
        self._seq = 0
        self._lock = threading.Lock()
        self._file = None
        self._closed = False
        atexit.register(self.close)

    # ------------------------------------------------------------------
    def emit(self, kind: str, **fields: Any) -> Dict[str, Any]:
        rec = {"ts": time.time(), "kind": kind}
        rec.update(fields)
        with self._lock:
            rec["seq"] = self._seq
            self._seq += 1
            self._buf.append(json.dumps(rec, default=_jsonable))
            if len(self._buf) >= self.flush_every:
                self._flush_locked()
        return rec

    def flush(self) -> None:
        with self._lock:
            self._flush_locked()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._flush_locked()
            if self._file is not None:
                self._file.close()
                self._file = None
            self._closed = True

    # ------------------------------------------------------------------
    def _open_locked(self):
        if self._file is None:
            os.makedirs(os.path.dirname(self.path), exist_ok=True)
            self._file = open(self.path, "a")
            self._closed = False
        return self._file

    def _flush_locked(self) -> None:
        if not self._buf:
            return
        data = "\n".join(self._buf) + "\n"
        self._buf.clear()
        f = self._open_locked()
        # rotate BEFORE writing so the live file always exists afterwards
        if self.max_bytes is not None and f.tell() \
                and f.tell() + len(data) > self.max_bytes:
            f.close()
            os.replace(self.path, self.path + ".1")
            self._file = None
            f = self._open_locked()
        f.write(data)
        f.flush()

    def __enter__(self) -> "EventSink":
        return self

    def __exit__(self, *exc: object) -> bool:
        self.close()
        return False


def _jsonable(o: Any) -> Any:
    for cast in (float, str):
        try:
            return cast(o)
        except Exception:       # noqa: BLE001 — best effort serialization
            continue
    return repr(o)


# --------------------------------------------------------------------------
# process-global sink (absent by default: event() is then a no-op)

_SINK: Optional[EventSink] = None


def set_sink(sink: Optional[EventSink]) -> Optional[EventSink]:
    """Install (or remove, with None) the global sink; returns the old one."""
    global _SINK
    prev, _SINK = _SINK, sink
    return prev


def get_sink() -> Optional[EventSink]:
    return _SINK


def event(kind: str, **fields: Any) -> Optional[Dict[str, Any]]:
    sink = _SINK
    if sink is None:
        return None
    return sink.emit(kind, **fields)
