"""Mamba-1 selective SSM block (jamba's mixer).

Training/prefill use ``jax.lax.associative_scan`` over time — the parallel
formulation that (a) maps onto the TPU as log-depth batched ops instead of a
sequential loop and (b) is fully visible to ``cost_analysis`` (no rolled
``while``; DESIGN.md §6). Decode is the O(1)-state sequential update.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = max(cfg.d_model // 16, 8)
    return d_inner, m.d_state, m.d_conv, dt_rank


def init_mamba(rng: jax.Array, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    di, n, dc, dtr = _dims(cfg)
    ks = jax.random.split(rng, 6)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], (dc, di), dtype, scale=0.5),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * n), dtype),
        "dt_proj": dense_init(ks[3], (dtr, di), dtype),
        "dt_bias": jnp.full((di,), -4.6, dtype),           # softplus ~ 0.01
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], (di, d), dtype),
    }


def _ssm_inputs(p: Params, xs: jax.Array, cfg: ModelConfig):
    """xs: (B, S, d_inner) post-conv/act -> per-step (dA, dBx, C)."""
    di, n, _, dtr = _dims(cfg)
    proj = jnp.einsum("bsi,ir->bsr", xs, p["x_proj"])
    dt, bmat, cmat = jnp.split(proj, [dtr, dtr + n], axis=-1)
    delta = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                # (B,S,di)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))           # (di, n)
    da = jnp.exp(delta[..., None] * a)                     # (B,S,di,n)
    dbx = (delta[..., None] * bmat[:, :, None, :].astype(jnp.float32)
           * xs[..., None].astype(jnp.float32))            # (B,S,di,n)
    return da, dbx, cmat


def _causal_conv(p: Params, x: jax.Array, dc: int) -> jax.Array:
    pad = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1]] * p["conv_w"][i] for i in range(dc))
    return jax.nn.silu(out + p["conv_b"])


def mamba_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                  cache: Optional[Dict[str, jax.Array]] = None,
                  return_state: bool = False):
    """x: (B, S, d). cache = {"conv": (B, dc-1, di), "ssm": (B, di, n)}."""
    di, n, dc, _ = _dims(cfg)
    b, s, d = x.shape
    xz = jnp.einsum("bsd,di->bsi", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)

    if cache is not None:
        assert s == 1
        conv_st = jnp.concatenate([cache["conv"], xs], axis=1)  # (B, dc, di)
        new_conv = conv_st[:, 1:]
        xc = jax.nn.silu(
            jnp.einsum("bci,ci->bi", conv_st, p["conv_w"]) + p["conv_b"]
        )[:, None]                                          # (B,1,di)
        da, dbx, cmat = _ssm_inputs(p, xc, cfg)
        h = cache["ssm"].astype(jnp.float32) * da[:, 0] + dbx[:, 0]
        y = jnp.einsum("bin,bn->bi", h, cmat[:, 0].astype(jnp.float32))
        y = y[:, None] + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
        out = jnp.einsum("bsi,id->bsd",
                         (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                         p["out_proj"])
        return out, {"conv": new_conv, "ssm": h.astype(cache["ssm"].dtype)}

    xc = _causal_conv(p, xs, dc)
    da, dbx, cmat = _ssm_inputs(p, xc, cfg)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (da, dbx), axis=1)  # (B,S,di,n)
    y = jnp.einsum("bsin,bsn->bsi", h, cmat.astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    out = jnp.einsum("bsi,id->bsd",
                     (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype),
                     p["out_proj"])
    state = None
    if return_state:
        state = {"conv": xs[:, -(dc - 1):].astype(x.dtype),
                 "ssm": h[:, -1].astype(x.dtype)}
    return out, state


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype) -> Dict[str, Any]:
    di, n, dc, _ = _dims(cfg)
    return {"conv": jnp.zeros((batch, dc - 1, di), dtype),
            "ssm": jnp.zeros((batch, di, n), dtype)}
