"""Mixture-of-experts: top-k router + capacity-bounded expert-parallel
dispatch under ``shard_map``.

TPU-native formulation (DESIGN.md §5): activations are already replicated
across the model axis between blocks, so each model shard *selects* the
assignments routed to its local experts from its resident tokens, scatters
them into a fixed-capacity buffer (E_local, C, d), runs the expert GLU as a
batched einsum, gathers back, and the partial outputs (plus f-sharded shared
experts) merge in ONE psum over the model axis — the same collective a
Megatron FFN already pays. No all-to-all, no replicated expert compute.

Token ranks within an expert use a sort-based positioning (O(T log T) and
O(T) memory instead of the (T, E) one-hot cumsum). Scatter/gather loop over
the k routing slots so per-slot temporaries are (T, d), not (T*k, d).

Capacity is per (expert, data shard) — GShard local-capacity semantics —
keeping iteration cost a pure function of sequence length, the property
SeqPoint relies on (DESIGN.md §7).

Without a mesh (unit tests / smoke), a mathematically identical single-device
path runs.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.axes import _resolve as _resolve_axis
from repro.dist.axes import current_mesh_axes
from repro.models.layers import act_fn, dense_init

try:                                    # jax >= 0.6 top-level export
    _shard_map = jax.shard_map
except AttributeError:                  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

Params = Dict[str, Any]


def expert_capacity(num_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    cap = num_tokens * m.experts_per_token / m.num_experts * m.capacity_factor
    return max(8, int(math.ceil(cap / 8.0)) * 8)


def init_moe(rng: jax.Array, cfg: ModelConfig, dtype) -> Params:
    m = cfg.moe
    d = cfg.d_model
    f = m.expert_d_ff or cfg.d_ff
    ks = jax.random.split(rng, 8)
    p: Params = {
        "router": dense_init(ks[0], (d, m.num_experts), jnp.float32),
        "e_wg": dense_init(ks[1], (m.num_experts, d, f), dtype),
        "e_wu": dense_init(ks[2], (m.num_experts, d, f), dtype),
        "e_wo": dense_init(ks[3], (m.num_experts, f, d), dtype),
    }
    if m.num_shared_experts:
        fs = f * m.num_shared_experts
        p["s_wg"] = dense_init(ks[4], (d, fs), dtype)
        p["s_wu"] = dense_init(ks[5], (d, fs), dtype)
        p["s_wo"] = dense_init(ks[6], (fs, d), dtype)
    return p


# ---------------------------------------------------------------------------
# routing helpers (shared by both paths; everything is per-shard local)


def _route(xt: jax.Array, router: jax.Array, k: int):
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    return probs, gate, eidx


def _positions(flat_e: jax.Array, num_experts: int) -> jax.Array:
    """Rank of each assignment within its expert (sort-based, stable)."""
    tk = flat_e.shape[0]
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    run_start = jnp.searchsorted(sorted_e, jnp.arange(num_experts))
    pos_sorted = jnp.arange(tk) - run_start[sorted_e]
    return jnp.zeros((tk,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))


def _expert_glu(buf: jax.Array, e_wg, e_wu, e_wo, act: str) -> jax.Array:
    g = jnp.einsum("ecd,edf->ecf", buf, e_wg)
    u = jnp.einsum("ecd,edf->ecf", buf, e_wu)
    return jnp.einsum("ecf,efd->ecd", act_fn(act)(g) * u, e_wo)


def _moe_math(xt, router, e_wg, e_wu, e_wo, cfg: ModelConfig, *,
              first_expert=None, num_experts_global: int = 0):
    """Single-shard MoE math over the (local) expert slice [first_expert,
    first_expert + E_loc). ``first_expert=None`` means all experts are
    local."""
    m = cfg.moe
    t, d = xt.shape
    e_loc = e_wg.shape[0]
    e_glob = num_experts_global or m.num_experts
    probs, gate, eidx = _route(xt, router, m.experts_per_token)
    cap = expert_capacity(t, cfg)

    flat_e = eidx.reshape(-1)
    pos = _positions(flat_e, e_glob).reshape(t, m.experts_per_token)

    if first_expert is None:
        local_e = eidx
        mine = jnp.ones_like(eidx, dtype=bool)
    else:
        local_e = eidx - first_expert
        mine = (local_e >= 0) & (local_e < e_loc)
    keep = mine & (pos < cap)
    dest_e = jnp.where(keep, local_e, 0)
    dest_c = jnp.where(keep, pos, cap)                     # cap col = spill

    buf = jnp.zeros((e_loc, cap + 1, d), xt.dtype)
    for slot in range(m.experts_per_token):
        buf = buf.at[dest_e[:, slot], dest_c[:, slot]].set(
            xt, mode="drop")
    y_buf = _expert_glu(buf[:, :cap], e_wg, e_wu, e_wo, cfg.act)
    y_buf = jnp.pad(y_buf, ((0, 0), (0, 1), (0, 0)))       # zero spill col

    y = jnp.zeros((t, d), jnp.float32)
    for slot in range(m.experts_per_token):
        contrib = y_buf[dest_e[:, slot], dest_c[:, slot]]
        contrib = jnp.where(keep[:, slot, None], contrib, 0.0)
        y = y + contrib.astype(jnp.float32) * gate[:, slot, None]

    # Switch-style load-balance loss (local estimate; counts via scatter-add
    # instead of a (T, k, E) one-hot)
    me = jnp.mean(probs, axis=0)
    counts = jnp.zeros((e_glob,), jnp.float32).at[flat_e].add(1.0)
    assign = counts / flat_e.shape[0]
    aux = e_glob * jnp.sum(me * assign) * m.router_aux_coef
    return y, aux


def _shared_glu(xt, s_wg, s_wu, s_wo, act: str) -> jax.Array:
    g = jnp.einsum("td,df->tf", xt, s_wg)
    u = jnp.einsum("td,df->tf", xt, s_wu)
    return jnp.einsum("tf,fd->td", act_fn(act)(g) * u, s_wo)


# ---------------------------------------------------------------------------
# entry point


def moe_forward(p: Params, x: jax.Array, cfg: ModelConfig, tp: int = 1,
                full_ep: bool = False) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss). Uses the shard_map EP path under a
    mesh, the plain path otherwise. ``full_ep`` shards experts over
    (data x model) with an all-to-all token exchange — see
    ``_moe_forward_full_ep`` (EXPERIMENTS.md §Perf hillclimb 1)."""
    m = cfg.moe
    b, s, d = x.shape
    axes = current_mesh_axes()
    if "model" in axes and full_ep:
        return _moe_forward_full_ep(p, x, cfg)
    if "model" in axes:
        return _moe_forward_sharded(p, x, cfg)

    xt = x.reshape(b * s, d)
    y, aux = _moe_math(xt, p["router"], p["e_wg"], p["e_wu"], p["e_wo"], cfg)
    if m.num_shared_experts:
        y = y + _shared_glu(xt, p["s_wg"], p["s_wu"], p["s_wo"],
                            cfg.act).astype(jnp.float32)
    return y.astype(x.dtype).reshape(b, s, d), aux


def _moe_forward_sharded(p: Params, x: jax.Array, cfg: ModelConfig):
    from jax._src import mesh as _mesh_lib

    mesh = _mesh_lib.thread_resources.env.physical_mesh
    m = cfg.moe
    b, s, d = x.shape
    axes = tuple(mesh.axis_names)
    dp_axes = tuple(a for a in axes if a in ("pod", "data"))
    dp_degree = 1
    for a in dp_axes:
        dp_degree *= mesh.shape[a]
    tp_degree = mesh.shape["model"]
    batch_split = dp_axes if (dp_axes and b % dp_degree == 0) else ()
    bspec = (batch_split if len(batch_split) != 1 else batch_split[0]) \
        if batch_split else None
    ep = m.num_experts % tp_degree == 0
    shared = bool(m.num_shared_experts)

    x_spec = P(bspec, None, None)
    if ep:
        ew_spec = (P("model", None, None), P("model", None, None),
                   P("model", None, None))
    else:
        ew_spec = (P(None, None, "model"), P(None, None, "model"),
                   P(None, "model", None))
    sw_spec = (P(None, "model"), P(None, "model"), P("model", None))

    def local_fn(x, router, e_wg, e_wu, e_wo, s_wg, s_wu, s_wo):
        bl, sl, _ = x.shape
        xt = x.reshape(bl * sl, d)
        if ep:
            e_loc = m.num_experts // tp_degree
            first = jax.lax.axis_index("model") * e_loc
        else:
            first = None
        y, aux = _moe_math(xt, router, e_wg, e_wu, e_wo, cfg,
                           first_expert=first,
                           num_experts_global=m.num_experts)
        if shared:
            y = y + _shared_glu(xt, s_wg, s_wu, s_wo,
                                cfg.act).astype(jnp.float32)
        y = jax.lax.psum(y.astype(x.dtype), "model")
        if not ep:
            # expert-TP computes every expert's f-shard: psum already merged
            pass
        if batch_split:
            aux = jax.lax.pmean(aux, dp_axes)
        aux = jax.lax.pmean(aux, "model")
        return y.reshape(bl, sl, d), aux

    if shared:
        sw = (p["s_wg"], p["s_wu"], p["s_wo"])
    else:
        sw = (jnp.zeros((1, 1), x.dtype),) * 3
        sw_spec = (P(None, None),) * 3
    fn = _shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), *ew_spec, *sw_spec),
        out_specs=(x_spec, P()))
    y, aux = fn(x, p["router"], p["e_wg"], p["e_wu"], p["e_wo"], *sw)
    return y, aux


def _moe_forward_full_ep(p: Params, x: jax.Array, cfg: ModelConfig):
    """Full expert parallelism over (data x model): each device owns
    E / num_devices experts RESIDENT (no ZeRO gathers, no cross-data expert
    gradient reduction), and tokens move to their experts through a
    fixed-capacity all-to-all — DeepSeek-V3's own EP design restated for the
    TPU mesh. Beyond-paper optimization; baseline keeps model-axis EP.

    Per device: send buffer (n_dev, C_pair, d) with C_pair =
    T_loc*k/n_dev*cf; a2a out, batched GLU over (E_loc, n_dev*C_pair, d),
    a2a back, gate-combine at the source. Gradients flow through the a2a
    transposes; expert weight grads stay device-local.
    """
    from jax._src import mesh as _mesh_lib

    mesh = _mesh_lib.thread_resources.env.physical_mesh
    m = cfg.moe
    b, s, d = x.shape
    axes = tuple(mesh.axis_names)
    ep_axes = tuple(a for a in axes if a in ("data", "model"))
    n_dev = 1
    for a in ep_axes:
        n_dev *= mesh.shape[a]
    assert m.num_experts % n_dev == 0, (m.num_experts, n_dev)
    e_loc = m.num_experts // n_dev
    dp_axes = tuple(a for a in axes if a in ("pod", "data"))
    dp_degree = 1
    for a in dp_axes:
        dp_degree *= mesh.shape[a]
    tp_size = mesh.shape["model"]
    assert b % dp_degree == 0
    bspec = dp_axes if len(dp_axes) > 1 else dp_axes[0]
    # train/prefill: tokens split over model on sequence so every EP rank
    # holds a distinct slice. decode (S < tp): tokens replicated over model,
    # assignments partitioned by routing slot across model ranks, outputs
    # psum'd — same a2a pattern, no divisibility constraint.
    seq_split = s % tp_size == 0 and s >= tp_size
    x_spec = P(bspec, "model" if seq_split else None, None)

    def local_fn(x, router, e_wg, e_wu, e_wo):
        bl, sl, _ = x.shape
        t = bl * sl
        xt = x.reshape(t, d)
        probs, gate, eidx = _route(xt, router, m.experts_per_token)
        # capacity per (source device, destination device) pair; no 8-row
        # floor — decode sends O(1) tokens per pair
        raw = t * m.experts_per_token / n_dev * m.capacity_factor
        cap = int(-(-raw // 8)) * 8 if raw > 8 else max(1, int(-(-raw // 1)))
        flat_e = eidx.reshape(-1)
        dest_dev = flat_e // e_loc
        dest_slot = flat_e % e_loc
        pos = _positions(dest_dev, n_dev).reshape(t, m.experts_per_token)
        keep = pos < cap
        if not seq_split:
            rank = jax.lax.axis_index("model")
            mine = (jnp.arange(t * m.experts_per_token) % tp_size) == rank
            keep = keep & mine.reshape(t, m.experts_per_token)
        dd = jnp.where(keep, dest_dev.reshape(t, -1), 0)
        dc = jnp.where(keep, pos, cap)
        send = jnp.zeros((n_dev, cap + 1, d), x.dtype)
        send_e = jnp.zeros((n_dev, cap + 1), jnp.int32)
        for slot in range(m.experts_per_token):
            send = send.at[dd[:, slot], dc[:, slot]].set(xt, mode="drop")
            send_e = send_e.at[dd[:, slot], dc[:, slot]].set(
                dest_slot.reshape(t, -1)[:, slot], mode="drop")
        send, send_e = send[:, :cap], send_e[:, :cap]
        recv = jax.lax.all_to_all(send, ep_axes, split_axis=0,
                                  concat_axis=0, tiled=True)
        recv_e = jax.lax.all_to_all(send_e, ep_axes, split_axis=0,
                                    concat_axis=0, tiled=True)
        rt = recv.reshape(n_dev * cap, d)
        re = recv_e.reshape(n_dev * cap)
        # dispatch received tokens into the local experts' buffers
        cap2 = n_dev * cap          # worst case: all land on one expert
        pos2 = _positions(re, e_loc)
        buf = jnp.zeros((e_loc, cap2, d), x.dtype)
        buf = buf.at[re, pos2].set(rt, mode="drop")
        y_buf = _expert_glu(buf, e_wg, e_wu, e_wo, cfg.act)
        y_tok = y_buf[re, pos2]
        back = y_tok.reshape(n_dev, cap, d)
        back = jax.lax.all_to_all(back, ep_axes, split_axis=0,
                                  concat_axis=0, tiled=True)
        back = jnp.pad(back, ((0, 0), (0, 1), (0, 0)))
        y = jnp.zeros((t, d), jnp.float32)
        for slot in range(m.experts_per_token):
            contrib = back[dd[:, slot], dc[:, slot]]
            contrib = jnp.where(keep[:, slot, None], contrib, 0.0)
            y = y + contrib.astype(jnp.float32) * gate[:, slot, None]
        y = y.astype(x.dtype)
        if not seq_split:
            y = jax.lax.psum(y, "model")     # slots partitioned over ranks
        me = jnp.mean(probs, axis=0)
        counts = jnp.zeros((m.num_experts,), jnp.float32).at[flat_e].add(1.0)
        aux = m.num_experts * jnp.sum(me * counts / flat_e.shape[0]) \
            * m.router_aux_coef
        aux = jax.lax.pmean(jax.lax.pmean(aux, dp_axes), "model")
        return y.reshape(bl, sl, d), aux

    ew_spec = tuple(P(("data", "model"), None, None) for _ in range(3))
    fn = _shard_map(
        local_fn, mesh=mesh,
        in_specs=(x_spec, P(None, None), *ew_spec),
        out_specs=(x_spec, P()))
    y, aux = fn(x, p["router"], p["e_wg"], p["e_wu"], p["e_wo"])
    if m.num_shared_experts:
        # shared experts stay TP-sharded in auto-SPMD land (partial-sum
        # psum handled by the partitioner; weights too big to replicate)
        xt = x.reshape(b * s, d)
        y = y + _shared_glu(xt, p["s_wg"], p["s_wu"],
                            p["s_wo"], cfg.act).astype(y.dtype).reshape(
                                b, s, d)
    return y, aux
