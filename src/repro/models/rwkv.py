"""RWKV-6 (Finch): time-mix with data-dependent decay + channel-mix.

Sequence mixing is computed in *chunked linear-attention* form: within a chunk
the recurrence is expanded into masked matmuls (MXU-friendly, fully visible to
cost analysis); across chunks the per-head (dh x dh) states compose through an
``associative_scan`` over affine maps. This is the TPU-native analogue of the
CUDA wkv kernels (DESIGN.md §3); ``repro.kernels.rwkv6_wkv`` implements the
same blocking in Pallas and is validated against the sequential oracle here.

Numerics: per-step log-decay is clamped to [-1, 0) so intra-chunk decay
products stay representable in fp32 (documented deviation, DESIGN.md §8).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]

W_LORA_DIM = 64
CHUNK = 64


def _dims(cfg: ModelConfig, tp: int = 1) -> Tuple[int, int]:
    """Head count padded to the TP degree (DESIGN.md §5: rwkv6-3b has 40
    heads; under 16-way TP we pad to 48 so shards hold whole heads)."""
    dh = cfg.rwkv_head_dim
    heads = cfg.d_model // dh
    if tp > 1 and heads % tp:
        heads = ((heads + tp - 1) // tp) * tp
    return heads, dh


def init_time_mix(rng: jax.Array, cfg: ModelConfig, dtype,
                  tp: int = 1) -> Params:
    d = cfg.d_model
    h, dh = _dims(cfg, tp)
    da = h * dh                                            # padded inner dim
    ks = jax.random.split(rng, 10)
    return {
        "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "w_r": dense_init(ks[0], (d, da), dtype),
        "w_k": dense_init(ks[1], (d, da), dtype),
        "w_v": dense_init(ks[2], (d, da), dtype),
        "w_g": dense_init(ks[3], (d, da), dtype),
        "w_o": dense_init(ks[4], (da, d), dtype),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A1) A2))
        "w0": jnp.full((da,), -2.0, dtype),
        "w_a1": dense_init(ks[5], (d, W_LORA_DIM), dtype),
        "w_a2": dense_init(ks[6], (W_LORA_DIM, da), dtype, scale=0.1),
        "u": dense_init(ks[7], (da,), dtype, scale=0.5),   # per-channel bonus
        "ln_w": jnp.ones((h, dh), dtype),                  # per-head groupnorm
        "ln_b": jnp.zeros((h, dh), dtype),
    }


def init_channel_mix(rng: jax.Array, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(rng, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "w_k": dense_init(ks[0], (d, f), dtype),
        "w_v": dense_init(ks[1], (f, d), dtype),
        "w_r": dense_init(ks[2], (d, d), dtype),
    }


def _shift(x: jax.Array, last: Optional[jax.Array]) -> jax.Array:
    """x_{t-1} along seq; ``last`` is the carried token for decode."""
    if last is not None:
        return last[:, None]
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _log_decay(p: Params, xw: jax.Array) -> jax.Array:
    ww = (p["w0"].astype(jnp.float32)
          + jnp.einsum("bsl,ld->bsd",
                       jnp.tanh(jnp.einsum("bsd,dl->bsl",
                                           xw.astype(jnp.float32),
                                           p["w_a1"].astype(jnp.float32))),
                       p["w_a2"].astype(jnp.float32)))
    return jnp.clip(-jnp.exp(ww), -1.0, -1e-6)             # log w per channel


def wkv6_sequential(r, k, v, lw, u, state):
    """Oracle recurrence. r,k,v,lw: (B,S,H,dh) fp32; state: (B,H,dh,dh).
    Returns (y, final_state). Used by tests and decode."""
    w = jnp.exp(lw)

    def step(s, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,dh,dh)
        # y = r . (S + diag(u) k v^T)
        y = jnp.einsum("bhk,bhkv->bhv", rt, s) + \
            jnp.einsum("bhk,bhk,bhv->bhv", rt, u[None] * kt, vt)
        s = wt[..., :, None] * s + kv
        return s, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    # reshape u to (H, dh)
    final, ys = jax.lax.scan(lambda s, x: step(s, x), state, xs)
    return jnp.moveaxis(ys, 0, 1), final


def wkv6_chunked(r, k, v, lw, u, state0, chunk: int = CHUNK):
    """Chunked-parallel wkv. Shapes (B,S,H,dh) fp32, state0 (B,H,dh,dh)."""
    b, s, h, dh = r.shape
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    rc, kc, vc, lwc = (t.reshape(b, n, chunk, h, dh) for t in (r, k, v, lw))
    cs = jnp.cumsum(lwc, axis=2)                           # inclusive cumsum
    total = cs[:, :, -1]                                   # (B,n,H,dh)
    # within-chunk pair decays: exp(cs_{i-1} - cs_j), j < i  (<= 1, safe)
    dec_q = jnp.exp(cs - lwc)                              # exp(cs_{i-1})
    dec_k = jnp.exp(-cs)                                   # exp(-cs_j) (>=1; |cs|<=C)
    rq = rc * dec_q
    kk = kc * dec_k
    att = jnp.einsum("bnihk,bnjhk->bnhij", rq, kk)         # (B,n,H,C,C)
    idx = jnp.arange(chunk)
    mask = (idx[:, None] > idx[None, :]).astype(att.dtype)
    diag = jnp.einsum("bnihk,bnihk->bnih", rc, u.reshape(1, 1, 1, h, dh) * kc)
    y_intra = jnp.einsum("bnhij,bnjhv->bnihv", att * mask, vc)
    y_intra = y_intra + diag[..., None] * vc

    # inter-chunk: U_c = sum_j (k_j * exp(total - cs_j)) v_j^T
    kdec = kc * jnp.exp(total[:, :, None] - cs)
    u_c = jnp.einsum("bnjhk,bnjhv->bnhkv", kdec, vc)       # (B,n,H,dh,dh)
    d_c = jnp.exp(total)                                   # (B,n,H,dh)

    def combine(e1, e2):
        d1, u1 = e1
        d2, u2 = e2
        return d1 * d2, u1 * d2[..., None] + u2

    dall, uall = jax.lax.associative_scan(combine, (d_c, u_c), axis=1)
    # state entering chunk i: scan result of chunks < i, composed with state0
    d_prev = jnp.concatenate(
        [jnp.ones_like(dall[:, :1]), dall[:, :-1]], axis=1)
    u_prev = jnp.concatenate(
        [jnp.zeros_like(uall[:, :1]), uall[:, :-1]], axis=1)
    s_in = state0[:, None] * d_prev[..., None] + u_prev    # (B,n,H,dh,dh)
    y_inter = jnp.einsum("bnihk,bnhkv->bnihv", rq, s_in)
    y = (y_intra + y_inter).reshape(b, s, h, dh)
    s_final = state0 * dall[:, -1][..., None] + uall[:, -1]
    return y, s_final


def time_mix_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                     cache: Optional[Dict[str, jax.Array]] = None,
                     return_state: bool = False, chunk: int = CHUNK):
    dh = cfg.rwkv_head_dim
    h = p["ln_w"].shape[0]                                 # padded head count
    b, s, d = x.shape
    last = cache["shift"] if cache is not None else None
    xx = _shift(x, last) - x
    xr = x + xx * p["mu_r"]
    xk = x + xx * p["mu_k"]
    xv = x + xx * p["mu_v"]
    xw = x + xx * p["mu_w"]
    xg = x + xx * p["mu_g"]
    f32 = jnp.float32
    r = jnp.einsum("bsd,de->bse", xr, p["w_r"]).astype(f32).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", xk, p["w_k"]).astype(f32).reshape(b, s, h, dh)
    v = jnp.einsum("bsd,de->bse", xv, p["w_v"]).astype(f32).reshape(b, s, h, dh)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]))
    lw = _log_decay(p, xw).reshape(b, s, h, dh)
    u = p["u"].astype(f32).reshape(h, dh)

    if cache is not None:
        assert s == 1
        y, s_new = wkv6_sequential(r, k, v, lw, u,
                                   cache["state"].astype(f32))
        new_cache = {"shift": x[:, -1], "state": s_new.astype(x.dtype)}
    else:
        state0 = jnp.zeros((b, h, dh, dh), f32)
        if s % chunk == 0 and s > chunk:
            y, s_fin = wkv6_chunked(r, k, v, lw, u, state0, chunk)
        else:
            y, s_fin = wkv6_sequential(r, k, v, lw, u, state0)
        new_cache = ({"shift": x[:, -1], "state": s_fin.astype(x.dtype)}
                     if return_state else None)

    # per-head groupnorm, gate, out-proj
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    yn = (y - mu) * jax.lax.rsqrt(var + 1e-5)
    yn = yn * p["ln_w"].astype(f32) + p["ln_b"].astype(f32)
    out = (yn.reshape(b, s, h * dh).astype(x.dtype) * g)
    return jnp.einsum("bsa,ad->bsd", out, p["w_o"]), new_cache


def channel_mix_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                        cache: Optional[Dict[str, jax.Array]] = None,
                        return_state: bool = False):
    last = cache["shift"] if cache is not None else None
    xx = _shift(x, last) - x
    xk = x + xx * p["mu_k"]
    xr = x + xx * p["mu_r"]
    kk = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["w_k"])))
    kv = jnp.einsum("bsf,fd->bsd", kk, p["w_v"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"])) * kv
    new_cache = None
    if cache is not None or return_state:
        new_cache = {"shift": x[:, -1]}
    return out, new_cache


def init_time_mix_cache(cfg: ModelConfig, batch: int, dtype,
                        tp: int = 1) -> Dict[str, Any]:
    h, dh = _dims(cfg, tp)
    return {"shift": jnp.zeros((batch, cfg.d_model), dtype),
            "state": jnp.zeros((batch, h, dh, dh), dtype)}


def init_channel_mix_cache(cfg: ModelConfig, batch: int, dtype):
    return {"shift": jnp.zeros((batch, cfg.d_model), dtype)}
