"""Attention: GQA/MHA with memory-sane chunked softmax, MLA, decode paths.

The chunked path is the pure-XLA analogue of the Pallas flash kernel
(``repro.kernels.flash_attention``): online softmax over KV chunks inside a
``lax.scan`` so S^2 score matrices are never materialized in HBM. The scan can
be unrolled for dry-run cost analysis (DESIGN.md §6).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope, dense_init, rms_norm

Params = Dict[str, Any]

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, num_q_heads: int) -> jax.Array:
    """(B, S, Hkv, dh) -> (B, S, Hq, dh) by repeating each group."""
    b, s, hkv, dh = k.shape
    if hkv == num_q_heads:
        return k
    reps = num_q_heads // hkv
    return jnp.repeat(k, reps, axis=2)


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, q_offset: int | jax.Array = 0,
                   kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Reference O(S^2)-memory attention. q:(B,Sq,H,dh) k/v:(B,Skv,H,dh)."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(skv)
    mask = None
    if causal:
        qpos = q_offset + jnp.arange(sq)
        mask = kpos[None, :] <= qpos[:, None]            # (Sq, Skv)
    if kv_valid_len is not None:
        vmask = kpos[None, :] < kv_valid_len[:, None]     # (B, Skv)
        vmask = vmask[:, None, None, :]
        scores = jnp.where(vmask, scores, NEG_INF)
    if mask is not None:
        scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, chunk: int, unroll: int = 1) -> jax.Array:
    """Online-softmax attention scanning over KV chunks (flash-style)."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    assert skv % chunk == 0, (skv, chunk)
    n = skv // chunk
    scale = 1.0 / math.sqrt(dh)
    kc = k.reshape(b, n, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(sq)

    def body(carry, xs):
        m, l, acc = carry
        j, kj, vj = xs
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            kpos = j * chunk + jnp.arange(chunk)
            mask = kpos[None, :] <= qpos[:, None]
            s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vj.dtype), vj)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), v.dtype)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n), kc, vc), unroll=max(unroll, 1))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
    return out.transpose(0, 2, 1, 3)                      # (B, Sq, H, dh)


def gqa_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_valid_len: jax.Array) -> jax.Array:
    """Single-step decode without expanding KV to query heads: the grouped
    einsum contracts the (possibly sequence-sharded) cache directly; under
    SPMD the softmax reductions become the flash-decode partial-max/sum
    combine. q: (B, 1, Hq, dh); k/v: (B, S, Hkv, dh)."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    q5 = q.reshape(b, sq, hkv, g, dh)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q5, k,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(k.shape[1])
    vmask = (kpos[None, :] < kv_valid_len[:, None])[:, None, None, None, :]
    scores = jnp.where(vmask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(b, sq, hq, dh)


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool,
                   chunk: int = 0, unroll: int = 1,
                   kv_valid_len: Optional[jax.Array] = None) -> jax.Array:
    """Dispatch between full and chunked paths. GQA repeat happens here."""
    if kv_valid_len is not None and q.shape[1] == 1 and not causal \
            and q.shape[2] % k.shape[2] == 0:
        return gqa_decode_attention(q, k, v, kv_valid_len)
    k = _repeat_kv(k, q.shape[2])
    v = _repeat_kv(v, q.shape[2])
    skv = k.shape[1]
    if chunk and skv % chunk == 0 and skv > chunk and kv_valid_len is None:
        return chunked_attention(q, k, v, causal=causal, chunk=chunk,
                                 unroll=unroll)
    return full_attention(q, k, v, causal=causal, kv_valid_len=kv_valid_len)


# ---------------------------------------------------------------------------
# GQA block


def init_gqa(rng: jax.Array, cfg: ModelConfig, dtype,
             num_q_heads: int) -> Params:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = num_q_heads, cfg.num_kv_heads
    ks = jax.random.split(rng, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, hq * dh), dtype),
        "wk": dense_init(ks[1], (d, hkv * dh), dtype),
        "wv": dense_init(ks[2], (d, hkv * dh), dtype),
        "wo": dense_init(ks[3], (hq * dh, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def gqa_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                positions: jax.Array, causal: bool = True, chunk: int = 0,
                unroll: int = 1,
                cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                cache_index: Optional[jax.Array] = None,
                return_kv: bool = False):
    """Self-attention. With ``cache=(K, V)`` and ``cache_index``, runs one
    decode step updating the cache in place (functionally)."""
    b, s, d = x.shape
    dh = cfg.resolved_head_dim
    hq = p["wq"].shape[1] // dh
    hkv = p["wk"].shape[1] // dh
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, s, hkv, dh)
    v = v.reshape(b, s, hkv, dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        ck, cv = cache
        assert s == 1, "cache path is a single decode step"
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 cache_index, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 cache_index, axis=1)
        new_cache = (ck, cv)
        valid = jnp.full((b,), cache_index + 1, jnp.int32)
        out = attention_core(q, ck, cv, causal=False, kv_valid_len=valid)
    else:
        out = attention_core(q, k, v, causal=causal, chunk=chunk,
                             unroll=unroll)
        if return_kv:
            new_cache = (k, v)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, hq * dh), p["wo"])
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3 multi-head latent attention)


def init_mla(rng: jax.Array, cfg: ModelConfig, dtype) -> Params:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim
    ks = jax.random.split(rng, 8)
    return {
        "w_dq": dense_init(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "w_uq": dense_init(ks[1], (m.q_lora_rank,
                                   h * (qk + m.qk_rope_head_dim)), dtype),
        "w_dkv": dense_init(ks[2], (d, m.kv_lora_rank), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_kr": dense_init(ks[3], (d, m.qk_rope_head_dim), dtype),
        "w_uk": dense_init(ks[4], (m.kv_lora_rank, h * qk), dtype),
        "w_uv": dense_init(ks[5], (m.kv_lora_rank, h * m.v_head_dim), dtype),
        "wo": dense_init(ks[6], (h * m.v_head_dim, d), dtype),
    }


def _mla_q(p: Params, x: jax.Array, cfg: ModelConfig, positions: jax.Array):
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    cq = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dq"]), p["q_norm"],
                  cfg.norm_eps)
    q = jnp.einsum("bsr,rh->bsh", cq, p["w_uq"]).reshape(
        b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p: Params, x: jax.Array, cfg: ModelConfig,
                positions: jax.Array):
    ckv = rms_norm(jnp.einsum("bsd,dr->bsr", x, p["w_dkv"]), p["kv_norm"],
                   cfg.norm_eps)
    kr = jnp.einsum("bsd,dr->bsr", x, p["w_kr"])          # shared rope key
    kr = apply_rope(kr[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return ckv, kr


def mla_forward(p: Params, x: jax.Array, cfg: ModelConfig, *,
                positions: jax.Array, chunk: int = 0, unroll: int = 1,
                cache: Optional[Tuple[jax.Array, jax.Array]] = None,
                cache_index: Optional[jax.Array] = None,
                return_kv: bool = False):
    """MLA. Cache holds the *compressed* latents (c_kv, k_rope): the serving
    memory win of MLA. Decode uses the absorbed formulation (q^T W_uk c_kv) so
    per-step work is O(S * kv_lora_rank) instead of O(S * H * dh)."""
    m, h = cfg.mla, cfg.num_heads
    b, s, d = x.shape
    q_nope, q_rope = _mla_q(p, x, cfg, positions)

    if cache is not None:
        c_cache, r_cache = cache
        assert s == 1
        ckv, kr = _mla_latent(p, x, cfg, positions)
        c_cache = jax.lax.dynamic_update_slice_in_dim(
            c_cache, ckv.astype(c_cache.dtype), cache_index, axis=1)
        r_cache = jax.lax.dynamic_update_slice_in_dim(
            r_cache, kr.astype(r_cache.dtype), cache_index, axis=1)
        # absorb W_uk into q: (B,1,H,nope) x (r, H*nope) -> (B,1,H,r)
        w_uk = p["w_uk"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
        q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
        scores = (jnp.einsum("bshr,btr->bhst", q_abs, c_cache,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshr,btr->bhst", q_rope, r_cache,
                               preferred_element_type=jnp.float32))
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        kpos = jnp.arange(c_cache.shape[1])
        valid = kpos[None, :] <= cache_index
        scores = jnp.where(valid[:, None, None, :] if valid.ndim == 2
                           else valid[None, None, None, :], scores * scale,
                           NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btr->bshr", probs.astype(c_cache.dtype),
                         c_cache)                          # (B,1,H,r)
        w_uv = p["w_uv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        o = jnp.einsum("bshr,rhv->bshv", ctx, w_uv)
        y = jnp.einsum("bsh,hd->bsd", o.reshape(b, s, h * m.v_head_dim),
                       p["wo"])
        return y, (c_cache, r_cache)

    # train / prefill: expanded form
    ckv, kr = _mla_latent(p, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rh->bsh", ckv, p["w_uk"]).reshape(
        b, s, h, m.qk_nope_head_dim)
    v = jnp.einsum("bsr,rh->bsh", ckv, p["w_uv"]).reshape(
        b, s, h, m.v_head_dim)
    k_rope = jnp.broadcast_to(kr[:, :, None, :], (b, s, h, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    # pad v up to qk head dim so the shared attention core applies
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    vpad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk_dim - m.v_head_dim)))
    out = attention_core(q, k, vpad, causal=True, chunk=chunk, unroll=unroll)
    out = out[..., :m.v_head_dim]
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, s, h * m.v_head_dim),
                   p["wo"])
    return y, ((ckv, kr) if return_kv else None)
