from repro.models.model_zoo import Model, build_from_run, build_model
from repro.models.transformer import Runtime, TransformerLM
from repro.models.encdec import EncDecLM

__all__ = ["Model", "Runtime", "TransformerLM", "EncDecLM", "build_model",
           "build_from_run"]
