"""``build_model(cfg, runtime)`` — dispatch to the right model class."""
from __future__ import annotations

from typing import Union

from repro.configs.base import ModelConfig, RunConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import Runtime, TransformerLM

Model = Union[TransformerLM, EncDecLM]


def build_model(cfg: ModelConfig, rt: Runtime = None) -> Model:
    rt = rt or Runtime()
    if cfg.encoder is not None:
        return EncDecLM(cfg, rt)
    return TransformerLM(cfg, rt)


def build_from_run(run: RunConfig) -> Model:
    return build_model(run.model, Runtime.from_run(run))
