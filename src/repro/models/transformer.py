"""Unified decoder-only LM over a configurable block pattern.

The layer stack is a ``lax.scan`` over *super-layers* (one interleave period
of the block pattern, e.g. jamba's 8-layer mamba/attention period), giving
O(1) trace/compile cost in depth. ``Runtime.unroll_layers`` unrolls the scan
for dry-run cost analysis (DESIGN.md §6); ``Runtime.remat`` checkpoints each
super-layer for training memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.ad_checkpoint
import jax.numpy as jnp

from repro.configs.base import BlockKind as BK
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig, StepKind
from repro.dist.axes import constrain
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import moe as moe_mod
from repro.models import rwkv as rw
from repro.models.layers import (
    Params,
    dense_init,
    embed_init,
    pad_heads,
    padded_vocab,
    rms_norm,
    softmax_xent,
)


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution knobs resolved from RunConfig + mesh (model code only sees
    this, never the mesh itself)."""

    tp_degree: int = 1
    attn_chunk: int = 0          # 0 = auto
    unroll_layers: bool = False
    attn_unroll: int = 1
    remat: str = "none"
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    moe_full_ep: bool = False

    @staticmethod
    def from_run(run: RunConfig) -> "Runtime":
        tp = run.mesh.model_degree if run.parallelism == "tp" else 1
        return Runtime(
            tp_degree=tp,
            attn_chunk=run.attn_chunk,
            unroll_layers=bool(run.unroll_layers),
            attn_unroll=max(run.unroll_layers, 1),
            remat=run.remat,
            param_dtype=jnp.dtype(run.param_dtype),
            compute_dtype=jnp.dtype(run.compute_dtype),
            moe_full_ep=run.moe_full_ep,
        )


AUTO_CHUNK_THRESHOLD = 8192
AUTO_CHUNK = 2048
MTP_LOSS_WEIGHT = 0.3
VLM_NUM_PATCHES = 2880           # anyres: 5 tiles x 576 patch tokens


def _auto_chunk(rt: Runtime, seq: int) -> int:
    if rt.attn_chunk:
        return rt.attn_chunk
    if seq >= AUTO_CHUNK_THRESHOLD:
        return AUTO_CHUNK
    return 0


# ---------------------------------------------------------------------------
# blocks


def init_ffn(rng: jax.Array, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(rng)
    return {"wi": dense_init(k1, (cfg.d_model, 2 * cfg.d_ff), dtype),
            "wo": dense_init(k2, (cfg.d_ff, cfg.d_model), dtype)}


def ffn_forward(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    g, u = jnp.split(h, 2, axis=-1)
    from repro.models.layers import act_fn
    return jnp.einsum("bsf,fd->bsd", act_fn(cfg.act)(g) * u, p["wo"])


def init_block(rng: jax.Array, cfg: ModelConfig, kinds: Tuple[BK, BK],
               rt: Runtime) -> Params:
    mixer_kind, ffn_kind = kinds
    dt = rt.param_dtype
    k1, k2 = jax.random.split(rng)
    p: Params = {"mixer_norm": jnp.ones((cfg.d_model,), dt),
                 "ffn_norm": jnp.ones((cfg.d_model,), dt)}
    if mixer_kind == BK.ATTENTION:
        hq = pad_heads(cfg.num_heads, rt.tp_degree)
        p["mixer"] = attn.init_gqa(k1, cfg, dt, hq)
    elif mixer_kind == BK.MLA:
        p["mixer"] = attn.init_mla(k1, cfg, dt)
    elif mixer_kind == BK.MAMBA:
        p["mixer"] = mb.init_mamba(k1, cfg, dt)
    elif mixer_kind == BK.RWKV:
        p["mixer"] = rw.init_time_mix(k1, cfg, dt, rt.tp_degree)
    else:
        raise ValueError(mixer_kind)
    if ffn_kind == BK.DENSE_FFN:
        p["ffn"] = init_ffn(k2, cfg, dt)
    elif ffn_kind == BK.MOE_FFN:
        p["ffn"] = moe_mod.init_moe(k2, cfg, dt)
    elif ffn_kind == BK.RWKV_CHANNEL:
        p["ffn"] = rw.init_channel_mix(k2, cfg, dt)
    else:
        raise ValueError(ffn_kind)
    return p


def init_block_cache(cfg: ModelConfig, kinds: Tuple[BK, BK], batch: int,
                     max_len: int, rt: Runtime) -> Dict[str, Any]:
    mixer_kind, ffn_kind = kinds
    dt = rt.compute_dtype
    dh = cfg.resolved_head_dim
    cache: Dict[str, Any] = {}
    if mixer_kind == BK.ATTENTION:
        cache["mixer"] = (jnp.zeros((batch, max_len, cfg.num_kv_heads, dh), dt),
                          jnp.zeros((batch, max_len, cfg.num_kv_heads, dh), dt))
    elif mixer_kind == BK.MLA:
        m = cfg.mla
        cache["mixer"] = (jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
                          jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt))
    elif mixer_kind == BK.MAMBA:
        cache["mixer"] = mb.init_mamba_cache(cfg, batch, dt)
    elif mixer_kind == BK.RWKV:
        cache["mixer"] = rw.init_time_mix_cache(cfg, batch, dt, rt.tp_degree)
    if ffn_kind == BK.RWKV_CHANNEL:
        cache["ffn"] = rw.init_channel_mix_cache(cfg, batch, dt)
    else:
        cache["ffn"] = {}
    return cache


def block_forward(p: Params, x: jax.Array, cfg: ModelConfig,
                  kinds: Tuple[BK, BK], rt: Runtime, *,
                  positions: jax.Array,
                  cache: Optional[Dict[str, Any]] = None,
                  cache_index: Optional[jax.Array] = None,
                  return_cache: bool = False, causal: bool = True):
    mixer_kind, ffn_kind = kinds
    aux = jnp.zeros((), jnp.float32)
    new_cache: Dict[str, Any] = {}
    chunk = _auto_chunk(rt, x.shape[1])
    h = rms_norm(x, p["mixer_norm"], cfg.norm_eps)
    mc = cache.get("mixer") if cache is not None else None
    def _name(t: jax.Array) -> jax.Array:
        # post-TP-collective intermediates; the save_boundaries remat
        # policy keeps them so recompute skips re-executing the
        # all-reduces (EXPERIMENTS.md §Perf)
        if rt.remat == "save_boundaries":
            return jax.ad_checkpoint.checkpoint_name(t, "block_boundary")
        return t

    if mixer_kind == BK.ATTENTION:
        y, c = attn.gqa_forward(p["mixer"], h, cfg, positions=positions,
                                causal=causal, chunk=chunk,
                                unroll=rt.attn_unroll, cache=mc,
                                cache_index=cache_index,
                                return_kv=return_cache)
    elif mixer_kind == BK.MLA:
        y, c = attn.mla_forward(p["mixer"], h, cfg, positions=positions,
                                chunk=chunk, unroll=rt.attn_unroll, cache=mc,
                                cache_index=cache_index,
                                return_kv=return_cache)
    elif mixer_kind == BK.MAMBA:
        y, c = mb.mamba_forward(p["mixer"], h, cfg, cache=mc,
                                return_state=return_cache)
    else:
        y, c = rw.time_mix_forward(p["mixer"], h, cfg, cache=mc,
                                   return_state=return_cache)
    if c is not None:
        new_cache["mixer"] = c
    x = x + _name(y)

    h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    fc = cache.get("ffn") if cache is not None else None
    if ffn_kind == BK.DENSE_FFN:
        y = ffn_forward(p["ffn"], h, cfg)
    elif ffn_kind == BK.MOE_FFN:
        y, aux = moe_mod.moe_forward(p["ffn"], h, cfg, rt.tp_degree,
                                     rt.moe_full_ep)
    else:
        y, c2 = rw.channel_mix_forward(p["ffn"], h, cfg,
                                       cache=fc if fc else None,
                                       return_state=return_cache)
        if c2 is not None:
            new_cache["ffn"] = c2
    if "ffn" not in new_cache:
        new_cache["ffn"] = {}
    return x + _name(y), new_cache, aux


# ---------------------------------------------------------------------------
# the model


class TransformerLM:
    """Decoder-only LM (all non-enc-dec assigned archs)."""

    def __init__(self, cfg: ModelConfig, rt: Runtime):
        assert cfg.num_layers % cfg.interleave_period == 0, cfg.name
        self.cfg = cfg
        self.rt = rt
        self.n_periods = cfg.num_layers // cfg.interleave_period
        self.vocab_p = padded_vocab(cfg.vocab_size)

    # -- params -----------------------------------------------------------
    def init(self, rng: jax.Array) -> Params:
        cfg, rt = self.cfg, self.rt
        k_emb, k_layers, k_head, k_mtp = jax.random.split(rng, 4)
        layer_keys = jax.random.split(k_layers, self.n_periods)

        def one_period(k):
            ks = jax.random.split(k, cfg.interleave_period)
            return tuple(init_block(ks[i], cfg, kinds, rt)
                         for i, kinds in enumerate(cfg.pattern))

        layers = jax.vmap(one_period)(layer_keys)   # leaves: (n_periods, ...)
        p: Params = {
            "embed": embed_init(k_emb, (self.vocab_p, cfg.d_model),
                                rt.param_dtype),
            "layers": layers,
            "final_norm": jnp.ones((cfg.d_model,), rt.param_dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = dense_init(k_head, (cfg.d_model, self.vocab_p),
                                      rt.param_dtype)
        if cfg.mtp_depth:
            km1, km2 = jax.random.split(k_mtp)
            p["mtp"] = {
                "proj": dense_init(km1, (2 * cfg.d_model, cfg.d_model),
                                   rt.param_dtype),
                "block": init_block(km2, cfg, cfg.pattern[0], rt),
                "norm_h": jnp.ones((cfg.d_model,), rt.param_dtype),
                "norm_e": jnp.ones((cfg.d_model,), rt.param_dtype),
            }
        return p

    # -- helpers ----------------------------------------------------------
    def _embed(self, p: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        x = p["embed"][batch["tokens"]].astype(self.rt.compute_dtype)
        x = constrain(x, "dp", None, None)
        if self.cfg.frontend == "image_patches" and "patches" in batch:
            x = jnp.concatenate(
                [batch["patches"].astype(self.rt.compute_dtype), x], axis=1)
        return x

    def _head(self, p: Params, x: jax.Array) -> jax.Array:
        x = rms_norm(x, p["final_norm"], self.cfg.norm_eps)
        w = p["embed"].T if self.cfg.tie_embeddings else p["lm_head"]
        return constrain(jnp.einsum("bsd,dv->bsv", x, w), "dp", None, "tp")

    def _stack(self, p: Params, x: jax.Array, positions: jax.Array, *,
               caches=None, cache_index=None, return_caches=False):
        cfg, rt = self.cfg, self.rt

        def super_layer(carry, xs):
            x, aux = carry
            layer_p, layer_cache = xs
            new_caches = []
            for j, kinds in enumerate(cfg.pattern):
                x, nc, a = block_forward(
                    layer_p[j], x, cfg, kinds, rt, positions=positions,
                    cache=None if layer_cache is None else layer_cache[j],
                    cache_index=cache_index, return_cache=return_caches)
                new_caches.append(nc)
                aux = aux + a
            return (x, aux), tuple(new_caches)

        fn = super_layer
        if rt.remat == "block":
            fn = jax.checkpoint(super_layer)
        elif rt.remat == "save_boundaries":
            fn = jax.checkpoint(
                super_layer,
                policy=jax.checkpoint_policies.save_only_these_names(
                    "block_boundary"))
        if caches is None:
            # scan xs must be arrays; thread a dummy index for the cache slot
            def fn_nocache(carry, xs_):
                layer_p, _ = xs_
                return fn(carry, (layer_p, None))

            (x, aux), caches_out = jax.lax.scan(
                fn_nocache, (x, jnp.zeros((), jnp.float32)),
                (p["layers"], jnp.arange(self.n_periods)),
                unroll=self.n_periods if rt.unroll_layers else 1)
        else:
            (x, aux), caches_out = jax.lax.scan(
                fn, (x, jnp.zeros((), jnp.float32)), (p["layers"], caches),
                unroll=self.n_periods if rt.unroll_layers else 1)
        return x, aux, caches_out

    # -- public entry points ----------------------------------------------
    def loss(self, p: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x = self._embed(p, batch)
        positions = jnp.arange(x.shape[1])
        x, aux, _ = self._stack(p, x, positions)
        labels = batch["labels"]
        if cfg.frontend == "image_patches" and "patches" in batch:
            # image positions carry no LM loss
            pad = jnp.full(batch["patches"].shape[:2], -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        logits = self._head(p, x)
        loss = softmax_xent(logits, labels, cfg.vocab_size)
        metrics = {"xent": loss, "aux": aux}
        if cfg.mtp_depth and "mtp" in p:
            loss_mtp = self._mtp_loss(p, x, batch, positions)
            metrics["mtp"] = loss_mtp
            loss = loss + MTP_LOSS_WEIGHT * loss_mtp
        return loss + aux, metrics

    def _mtp_loss(self, p: Params, h: jax.Array, batch, positions):
        """DeepSeek-V3-style multi-token prediction: one extra block predicts
        token t+2 from [h_t ; emb(token_{t+1})]."""
        cfg, rt = self.cfg, self.rt
        mtp = p["mtp"]
        tokens, labels = batch["tokens"], batch["labels"]
        emb_next = p["embed"][jnp.roll(tokens, -1, axis=1)].astype(h.dtype)
        feat = jnp.concatenate([
            rms_norm(h, mtp["norm_h"], cfg.norm_eps),
            rms_norm(emb_next, mtp["norm_e"], cfg.norm_eps)], axis=-1)
        if cfg.frontend == "image_patches" and "patches" in batch:
            feat = feat[:, batch["patches"].shape[1]:]
        x = jnp.einsum("bsd,de->bse", feat, mtp["proj"])

        def mtp_block(bp, xx):
            return block_forward(bp, xx, cfg, cfg.pattern[0], rt,
                                 positions=jnp.arange(xx.shape[1]))[0]

        if rt.remat == "block":
            mtp_block = jax.checkpoint(mtp_block)
        x = mtp_block(mtp["block"], x)
        logits = self._head(p, x)
        labels2 = jnp.concatenate(
            [labels[:, 1:], jnp.full_like(labels[:, :1], -1)], axis=1)
        return softmax_xent(logits, labels2, cfg.vocab_size)

    def prefill(self, p: Params, batch: Dict[str, jax.Array],
                pos0: jax.Array | int = 0):
        """Prefill a prompt. ``pos0`` offsets the rope positions so a prompt
        can be placed at an absolute cache offset (continuous-batching slot
        admission); the causal mask is local to the window either way."""
        x = self._embed(p, batch)
        positions = jnp.asarray(pos0, jnp.int32) + jnp.arange(x.shape[1])
        x, _, caches = self._stack(p, x, positions, return_caches=True)
        logits = self._head(p, x[:, -1:])
        return logits, caches

    def init_cache(self, batch: int, max_len: int):
        cfg, rt = self.cfg, self.rt

        def one(_):
            return tuple(init_block_cache(cfg, kinds, batch, max_len, rt)
                         for kinds in cfg.pattern)

        # stacked over periods to match the scan layout
        return jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one(i) for i in range(self.n_periods)])

    def decode_step(self, p: Params, caches, token: jax.Array,
                    cache_index: jax.Array):
        """token: (B, 1) int32; cache_index: scalar int32 (current length)."""
        x = p["embed"][token].astype(self.rt.compute_dtype)
        positions = cache_index[None] if cache_index.ndim == 0 \
            else cache_index
        x, _, new_caches = self._stack(p, x, positions, caches=caches,
                                       cache_index=cache_index)
        logits = self._head(p, x)
        return logits[:, 0], new_caches

    # -- specs --------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        if shape.step == StepKind.TRAIN or shape.step == StepKind.PREFILL:
            if cfg.frontend == "image_patches":
                n_img = min(VLM_NUM_PATCHES, s // 2)
                specs = {
                    "tokens": jax.ShapeDtypeStruct((b, s - n_img), jnp.int32),
                    "patches": jax.ShapeDtypeStruct((b, n_img, cfg.d_model),
                                                    self.rt.compute_dtype),
                }
                if shape.step == StepKind.TRAIN:
                    specs["labels"] = jax.ShapeDtypeStruct((b, s - n_img),
                                                           jnp.int32)
                return specs
            specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
            if shape.step == StepKind.TRAIN:
                specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            return specs
        # decode: one token against a seq_len cache
        return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "cache_index": jax.ShapeDtypeStruct((), jnp.int32)}
