"""Shared building blocks: inits, norms, RoPE, embeddings.

Parameters are plain dict pytrees. Leaf *paths* carry the semantics the
sharding rules key on (see ``repro.dist.sharding``): e.g. any leaf whose path
ends in ``.../wi`` is a column-parallel FFN kernel.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]

VOCAB_MULTIPLE = 128


def padded_vocab(vocab_size: int, multiple: int = VOCAB_MULTIPLE) -> int:
    return ((vocab_size + multiple - 1) // multiple) * multiple


def pad_heads(num_heads: int, degree: int) -> int:
    """Pad head count up to a multiple of the TP degree (DESIGN.md §5)."""
    return ((num_heads + degree - 1) // degree) * degree


# ---------------------------------------------------------------------------
# init


def dense_init(rng: jax.Array, shape: Tuple[int, ...], dtype,
               scale: Optional[float] = None) -> jax.Array:
    fan_in = shape[0] if len(shape) > 1 else 1
    std = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def embed_init(rng: jax.Array, shape: Tuple[int, ...], dtype) -> jax.Array:
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * weight.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# rotary position embedding


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses


def softmax_xent(logits: jax.Array, labels: jax.Array,
                 vocab_size: int) -> jax.Array:
    """Mean token cross-entropy; ignores label == -1 and padded vocab tail."""
    logits = logits.astype(jnp.float32)
    # mask padded vocab entries so they never receive probability mass
    if logits.shape[-1] > vocab_size:
        neg = jnp.full((logits.shape[-1] - vocab_size,), -1e9, logits.dtype)
        logits = logits.at[..., vocab_size:].set(neg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
