"""The paper's SQNNs: GNMT (LSTM enc-dec + attention) and DeepSpeech2
(conv + bi-GRU + CTC), in JAX (paper §VI-B).

These power the *wallclock* reproduction: per-iteration runtime really is a
function of the padded input SL (cells unroll via ``lax.scan`` over time).
Reduced-size presets keep a CPU iteration in the tens of milliseconds while
preserving the layer structure the paper profiles.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, embed_init, softmax_xent


# ---------------------------------------------------------------------------
# cells


def init_lstm(rng, d_in: int, d_h: int, dtype=jnp.float32) -> Params:
    k1, k2 = jax.random.split(rng)
    return {"w": dense_init(k1, (d_in + d_h, 4 * d_h), dtype),
            "b": jnp.zeros((4 * d_h,), dtype)}


def lstm_cell(p: Params, carry, x):
    h, c = carry
    z = jnp.concatenate([x, h], axis=-1) @ p["w"] + p["b"]
    i, f, g, o = jnp.split(z, 4, axis=-1)
    c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def init_gru(rng, d_in: int, d_h: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    return {"wzr": dense_init(k1, (d_in + d_h, 2 * d_h), dtype),
            "wx": dense_init(k2, (d_in, d_h), dtype),
            "wh": dense_init(k3, (d_h, d_h), dtype),
            "b": jnp.zeros((2 * d_h,), dtype)}


def gru_cell(p: Params, h, x):
    zr = jnp.concatenate([x, h], axis=-1) @ p["wzr"] + p["b"]
    z, r = jnp.split(jax.nn.sigmoid(zr), 2, axis=-1)
    n = jnp.tanh(x @ p["wx"] + (r * h) @ p["wh"])
    h = (1 - z) * n + z * h
    return h, h


def run_lstm(p: Params, xs: jax.Array, reverse: bool = False) -> jax.Array:
    """xs: (B, S, d) -> (B, S, h)."""
    b, s, _ = xs.shape
    d_h = p["w"].shape[1] // 4
    h0 = (jnp.zeros((b, d_h), xs.dtype), jnp.zeros((b, d_h), xs.dtype))
    xs_t = jnp.moveaxis(xs, 1, 0)
    _, hs = jax.lax.scan(lambda c, x: lstm_cell(p, c, x), h0, xs_t,
                         reverse=reverse)
    return jnp.moveaxis(hs, 0, 1)


def run_gru(p: Params, xs: jax.Array, reverse: bool = False) -> jax.Array:
    b, s, _ = xs.shape
    d_h = p["wx"].shape[1]
    h0 = jnp.zeros((b, d_h), xs.dtype)
    xs_t = jnp.moveaxis(xs, 1, 0)
    _, hs = jax.lax.scan(lambda c, x: gru_cell(p, c, x), h0, xs_t,
                         reverse=reverse)
    return jnp.moveaxis(hs, 0, 1)


def bidir(run_fn, p_fwd: Params, p_bwd: Params, xs: jax.Array) -> jax.Array:
    return jnp.concatenate([run_fn(p_fwd, xs), run_fn(p_bwd, xs, True)],
                           axis=-1)


# ---------------------------------------------------------------------------
# GNMT (paper §VI-B: 1 bi + 7 uni encoder LSTM, 8 decoder LSTM, attention,
# FC). ``num_enc_uni``/``num_dec`` shrink for the CPU reproduction.


@dataclass(frozen=True)
class GNMTConfig:
    vocab_size: int = 32_000
    d_model: int = 1024
    num_enc_uni: int = 7
    num_dec: int = 8
    dtype: Any = jnp.float32

    def reduced(self) -> "GNMTConfig":
        return dataclasses.replace(self, vocab_size=4096, d_model=160,
                                   num_enc_uni=2, num_dec=3)


class GNMT:
    def __init__(self, cfg: GNMTConfig):
        self.cfg = cfg

    def init(self, rng) -> Params:
        c = self.cfg
        d = c.d_model
        ks = iter(jax.random.split(rng, 16 + c.num_enc_uni + c.num_dec))
        p: Params = {
            "src_embed": embed_init(next(ks), (c.vocab_size, d), c.dtype),
            "tgt_embed": embed_init(next(ks), (c.vocab_size, d), c.dtype),
            "enc_bi_f": init_lstm(next(ks), d, d // 2, c.dtype),
            "enc_bi_b": init_lstm(next(ks), d, d // 2, c.dtype),
            "enc_uni": [init_lstm(next(ks), d, d, c.dtype)
                        for _ in range(c.num_enc_uni)],
            "dec": [init_lstm(next(ks), d if i else 2 * d, d, c.dtype)
                    for i in range(c.num_dec)],
            "attn_q": dense_init(next(ks), (d, d), c.dtype),
            "out_proj": dense_init(next(ks), (2 * d, d), c.dtype),
            "head": dense_init(next(ks), (d, c.vocab_size), c.dtype),
        }
        return p

    def encode(self, p: Params, src: jax.Array) -> jax.Array:
        x = p["src_embed"][src]
        x = bidir(run_lstm, p["enc_bi_f"], p["enc_bi_b"], x)
        for i, lp in enumerate(p["enc_uni"]):
            y = run_lstm(lp, x)
            x = x + y if i > 0 else y                      # residual stack
        return x

    def loss(self, p: Params, batch: Dict[str, jax.Array]):
        c = self.cfg
        enc = self.encode(p, batch["src"])                 # (B, Ss, d)
        x = p["tgt_embed"][batch["tgt"]]                   # (B, St, d)
        # first decoder layer consumes [emb; attention context]
        q = run_lstm(p["dec"][0], jnp.concatenate(
            [x, jnp.zeros_like(x)], axis=-1))
        scores = jnp.einsum("btd,bsd->bts", q @ p["attn_q"], enc)
        smask = (batch["src"] > 0)[:, None, :]
        scores = jnp.where(smask, scores, -1e30)
        ctx = jnp.einsum("bts,bsd->btd", jax.nn.softmax(scores, -1), enc)
        h = jnp.tanh(jnp.concatenate([q, ctx], -1) @ p["out_proj"])
        for i, lp in enumerate(p["dec"][1:]):
            y = run_lstm(lp, h)
            h = h + y
        logits = h @ p["head"]
        loss = softmax_xent(logits, batch["labels"], c.vocab_size)
        return loss, {"xent": loss}

    def make_batch(self, rng, batch_size: int, src_len: int, tgt_len: int):
        import numpy as np
        r = np.random.RandomState(rng)
        v = self.cfg.vocab_size
        return {
            "src": jnp.asarray(
                r.randint(1, v, size=(batch_size, src_len), dtype=np.int32)),
            "tgt": jnp.asarray(
                r.randint(1, v, size=(batch_size, tgt_len), dtype=np.int32)),
            "labels": jnp.asarray(
                r.randint(0, v, size=(batch_size, tgt_len), dtype=np.int32)),
        }


# ---------------------------------------------------------------------------
# DeepSpeech2 (paper §VI-B: 2 conv, 5 bi-GRU, 1 FC, batch-norm, CTC)


@dataclass(frozen=True)
class DS2Config:
    num_freq: int = 161
    conv_channels: int = 32
    d_h: int = 800
    num_gru: int = 5
    vocab_size: int = 29                                   # chars + blank
    dtype: Any = jnp.float32

    def reduced(self) -> "DS2Config":
        return dataclasses.replace(self, num_freq=64, conv_channels=8,
                                   d_h=96, num_gru=3)


class DS2:
    def __init__(self, cfg: DS2Config):
        self.cfg = cfg

    def init(self, rng) -> Params:
        c = self.cfg
        ks = iter(jax.random.split(rng, 8 + 2 * c.num_gru))
        f_out = c.num_freq // 4
        p: Params = {
            "conv1": dense_init(next(ks), (11, 41, 1, c.conv_channels),
                                c.dtype, scale=0.05),
            "conv2": dense_init(next(ks), (11, 21, c.conv_channels,
                                           c.conv_channels), c.dtype,
                                scale=0.05),
            "bn_scale": jnp.ones((c.conv_channels,), c.dtype),
            "bn_bias": jnp.zeros((c.conv_channels,), c.dtype),
            "gru": [
                (init_gru(next(ks),
                          f_out * c.conv_channels if i == 0 else 2 * c.d_h,
                          c.d_h, c.dtype),
                 init_gru(next(ks),
                          f_out * c.conv_channels if i == 0 else 2 * c.d_h,
                          c.d_h, c.dtype))
                for i in range(c.num_gru)],
            "head": dense_init(next(ks), (2 * c.d_h, c.vocab_size), c.dtype),
        }
        return p

    def _frontend(self, p: Params, spec: jax.Array) -> jax.Array:
        """spec: (B, T, F) -> (B, T/4, F/4 * C) via two strided convs."""
        x = spec[:, None]                                  # (B, 1, T, F)
        x = jax.lax.conv_general_dilated(
            x, jnp.moveaxis(p["conv1"], (0, 1, 2, 3), (2, 3, 1, 0)),
            window_strides=(2, 2), padding="SAME")
        x = jax.nn.relu(x)
        x = jax.lax.conv_general_dilated(
            x, jnp.moveaxis(p["conv2"], (0, 1, 2, 3), (2, 3, 1, 0)),
            window_strides=(2, 2), padding="SAME")
        # batch-norm over (B, T, F) per channel
        mu = jnp.mean(x, axis=(0, 2, 3), keepdims=True)
        var = jnp.var(x, axis=(0, 2, 3), keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-5)
        x = x * p["bn_scale"][None, :, None, None] \
            + p["bn_bias"][None, :, None, None]
        x = jax.nn.relu(x)
        b, ch, t, f = x.shape
        return jnp.moveaxis(x, 1, 3).reshape(b, t, f * ch)

    def logits(self, p: Params, spec: jax.Array) -> jax.Array:
        x = self._frontend(p, spec)
        for pf, pb in p["gru"]:
            x = bidir(run_gru, pf, pb, x)
        return x @ p["head"]

    def loss(self, p: Params, batch: Dict[str, jax.Array]):
        logits = self.logits(p, batch["spec"])
        loss = ctc_loss(logits, batch["labels"], batch["label_lens"])
        return loss, {"ctc": loss}

    def make_batch(self, rng, batch_size: int, num_frames: int,
                   label_len: int = 0):
        import numpy as np
        r = np.random.RandomState(rng)
        c = self.cfg
        label_len = label_len or max(2, num_frames // 32)
        return {
            "spec": jnp.asarray(r.randn(batch_size, num_frames,
                                        c.num_freq).astype(np.float32)),
            "labels": jnp.asarray(r.randint(
                1, c.vocab_size, size=(batch_size, label_len),
                dtype=np.int32)),
            "label_lens": jnp.full((batch_size,), label_len, jnp.int32),
        }


# ---------------------------------------------------------------------------
# CTC (log-semiring forward algorithm; blank = 0)


def ctc_loss(logits: jax.Array, labels: jax.Array,
             label_lens: jax.Array) -> jax.Array:
    """logits: (B, T, V); labels: (B, L) (0 = pad); mean -log p(labels)."""
    b, t, v = logits.shape
    l = labels.shape[1]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    # extended sequence z' = [blank, l1, blank, l2, ..., blank]: (B, 2L+1)
    ext = jnp.zeros((b, 2 * l + 1), jnp.int32)
    ext = ext.at[:, 1::2].set(labels)
    ext_valid = jnp.arange(2 * l + 1)[None] < (2 * label_lens + 1)[:, None]
    # allow skip from s-2 when ext[s] != blank and ext[s] != ext[s-2]
    can_skip = jnp.concatenate(
        [jnp.zeros((b, 2), bool),
         (ext[:, 2:] != 0) & (ext[:, 2:] != ext[:, :-2])], axis=1)

    neg = jnp.float32(-1e30)
    alpha0 = jnp.full((b, 2 * l + 1), neg)
    alpha0 = alpha0.at[:, 0].set(logp[:, 0, 0])
    alpha0 = alpha0.at[:, 1].set(
        jnp.take_along_axis(logp[:, 0], ext[:, 1:2], axis=1)[:, 0])

    def step(alpha, logp_t):
        stay = alpha
        prev1 = jnp.concatenate([jnp.full((b, 1), neg), alpha[:, :-1]], 1)
        prev2 = jnp.concatenate([jnp.full((b, 2), neg), alpha[:, :-2]], 1)
        prev2 = jnp.where(can_skip, prev2, neg)
        merged = jnp.logaddexp(jnp.logaddexp(stay, prev1), prev2)
        emit = jnp.take_along_axis(logp_t, ext, axis=1)
        alpha = jnp.where(ext_valid, merged + emit, neg)
        return alpha, None

    alpha, _ = jax.lax.scan(step, alpha0,
                            jnp.moveaxis(logp[:, 1:], 1, 0))
    last = 2 * label_lens
    ll = jnp.logaddexp(
        jnp.take_along_axis(alpha, last[:, None], axis=1)[:, 0],
        jnp.take_along_axis(alpha, jnp.maximum(last - 1, 0)[:, None],
                            axis=1)[:, 0])
    return -jnp.mean(ll)
