"""Encoder-decoder transformer (whisper-medium backbone).

The conv/mel frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, source_len, d_model). Encoder is
bidirectional; decoder layers are self-attn (causal, cached) + cross-attn
(keys/values precomputed once at prefill) + FFN. Whisper uses LayerNorm and
learned positions.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, StepKind
from repro.dist.axes import constrain
from repro.models import attention as attn
from repro.models.layers import (
    Params,
    dense_init,
    embed_init,
    layer_norm,
    pad_heads,
    padded_vocab,
    softmax_xent,
)
from repro.models.transformer import Runtime, _auto_chunk


def _ln_init(d: int, dt) -> Params:
    return {"w": jnp.ones((d,), dt), "b": jnp.zeros((d,), dt)}


def _ffn_init(rng, cfg: ModelConfig, dt) -> Params:
    k1, k2 = jax.random.split(rng)
    return {"wi": dense_init(k1, (cfg.d_model, cfg.d_ff), dt),
            "bi": jnp.zeros((cfg.d_ff,), dt),
            "wo": dense_init(k2, (cfg.d_ff, cfg.d_model), dt),
            "bo": jnp.zeros((cfg.d_model,), dt)}


def _ffn(p: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"]) + p["bi"])
    return jnp.einsum("bsf,fd->bsd", h, p["wo"]) + p["bo"]


def _mha_init(rng, cfg: ModelConfig, dt, tp: int) -> Params:
    d = cfg.d_model
    h = pad_heads(cfg.num_heads, tp)
    dh = cfg.resolved_head_dim
    ks = jax.random.split(rng, 4)
    return {"wq": dense_init(ks[0], (d, h * dh), dt),
            "wk": dense_init(ks[1], (d, h * dh), dt),
            "wv": dense_init(ks[2], (d, h * dh), dt),
            "wo": dense_init(ks[3], (h * dh, d), dt)}


def _mha(p: Params, xq: jax.Array, xkv: jax.Array, *, causal: bool,
         chunk: int, unroll: int, dh: int,
         kv: Optional[Tuple[jax.Array, jax.Array]] = None,
         cache: Optional[Tuple[jax.Array, jax.Array]] = None,
         cache_index=None, return_kv: bool = False):
    b, sq, d = xq.shape
    hq = p["wq"].shape[1] // dh
    q = jnp.einsum("bsd,dh->bsh", xq, p["wq"]).reshape(b, sq, hq, dh)
    new_cache = None
    if kv is not None:                       # cross-attn with precomputed K/V
        k, v = kv
        out = attn.attention_core(q, k, v, causal=False, chunk=chunk,
                                  unroll=unroll)
    else:
        k = jnp.einsum("bsd,dh->bsh", xkv, p["wk"]).reshape(b, -1, hq, dh)
        v = jnp.einsum("bsd,dh->bsh", xkv, p["wv"]).reshape(b, -1, hq, dh)
        if cache is not None:
            ck, cv = cache
            ck = jax.lax.dynamic_update_slice_in_dim(
                ck, k.astype(ck.dtype), cache_index, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(
                cv, v.astype(cv.dtype), cache_index, axis=1)
            new_cache = (ck, cv)
            valid = jnp.full((b,), cache_index + 1, jnp.int32)
            out = attn.attention_core(q, ck, cv, causal=False,
                                      kv_valid_len=valid)
        else:
            out = attn.attention_core(q, k, v, causal=causal, chunk=chunk,
                                      unroll=unroll)
            if return_kv:
                new_cache = (k, v)
    y = jnp.einsum("bsh,hd->bsd", out.reshape(b, sq, hq * dh), p["wo"])
    return y, new_cache


class EncDecLM:
    """Whisper-style enc-dec; API mirrors TransformerLM."""

    def __init__(self, cfg: ModelConfig, rt: Runtime):
        self.cfg, self.rt = cfg, rt
        self.vocab_p = padded_vocab(cfg.vocab_size)

    def init(self, rng: jax.Array) -> Params:
        cfg, rt = self.cfg, self.rt
        dt = rt.param_dtype
        d = cfg.d_model
        ks = jax.random.split(rng, 8)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {"attn": _mha_init(k1, cfg, dt, rt.tp_degree),
                    "attn_ln": _ln_init(d, dt),
                    "ffn": _ffn_init(k2, cfg, dt), "ffn_ln": _ln_init(d, dt)}

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {"self": _mha_init(k1, cfg, dt, rt.tp_degree),
                    "self_ln": _ln_init(d, dt),
                    "cross": _mha_init(k2, cfg, dt, rt.tp_degree),
                    "cross_ln": _ln_init(d, dt),
                    "ffn": _ffn_init(k3, cfg, dt), "ffn_ln": _ln_init(d, dt)}

        enc_keys = jax.random.split(ks[0], cfg.encoder.num_layers)
        dec_keys = jax.random.split(ks[1], cfg.num_layers)
        return {
            "enc_layers": jax.vmap(enc_layer)(enc_keys),
            "dec_layers": jax.vmap(dec_layer)(dec_keys),
            "enc_pos": embed_init(ks[2], (cfg.encoder.max_source_len, d), dt),
            "dec_pos": embed_init(ks[3], (cfg.max_position, d), dt),
            "embed": embed_init(ks[4], (self.vocab_p, d), dt),
            "enc_ln": _ln_init(d, dt),
            "dec_ln": _ln_init(d, dt),
        }

    # -- encoder ------------------------------------------------------------
    def encode(self, p: Params, frames: jax.Array) -> jax.Array:
        cfg, rt = self.cfg, self.rt
        dh = cfg.resolved_head_dim
        s = frames.shape[1]
        x = frames.astype(rt.compute_dtype) + \
            p["enc_pos"][:s].astype(rt.compute_dtype)
        x = constrain(x, "dp", None, None)
        chunk = _auto_chunk(rt, s)

        def layer(x, lp):
            h = layer_norm(x, lp["attn_ln"]["w"], lp["attn_ln"]["b"])
            y, _ = _mha(lp["attn"], h, h, causal=False, chunk=chunk,
                        unroll=rt.attn_unroll, dh=dh)
            x = x + y
            h = layer_norm(x, lp["ffn_ln"]["w"], lp["ffn_ln"]["b"])
            return x + _ffn(lp["ffn"], h), None

        if rt.remat == "block":
            layer = jax.checkpoint(layer)
        x, _ = jax.lax.scan(layer, x, p["enc_layers"],
                            unroll=(cfg.encoder.num_layers
                                    if rt.unroll_layers else 1))
        return layer_norm(x, p["enc_ln"]["w"], p["enc_ln"]["b"])

    def _cross_kv(self, p: Params, enc_out: jax.Array):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b, s, _ = enc_out.shape

        def one(lp):
            h = lp["cross"]["wk"].shape[1] // dh
            k = jnp.einsum("bsd,dh->bsh", enc_out,
                           lp["cross"]["wk"]).reshape(b, s, h, dh)
            v = jnp.einsum("bsd,dh->bsh", enc_out,
                           lp["cross"]["wv"]).reshape(b, s, h, dh)
            return k, v

        return jax.vmap(one)(p["dec_layers"])

    # -- decoder ------------------------------------------------------------
    def _decoder(self, p: Params, x: jax.Array, cross_kv, *,
                 caches=None, cache_index=None, return_caches=False):
        cfg, rt = self.cfg, self.rt
        dh = cfg.resolved_head_dim
        chunk = _auto_chunk(rt, x.shape[1])

        def layer(x, lp, ckv, cache):
            h = layer_norm(x, lp["self_ln"]["w"], lp["self_ln"]["b"])
            y, nc = _mha(lp["self"], h, h, causal=True, chunk=chunk,
                         unroll=rt.attn_unroll, dh=dh, cache=cache,
                         cache_index=cache_index, return_kv=return_caches)
            x = x + y
            h = layer_norm(x, lp["cross_ln"]["w"], lp["cross_ln"]["b"])
            y, _ = _mha(lp["cross"], h, None, causal=False, chunk=chunk,
                        unroll=rt.attn_unroll, dh=dh, kv=ckv)
            x = x + y
            h = layer_norm(x, lp["ffn_ln"]["w"], lp["ffn_ln"]["b"])
            return x + _ffn(lp["ffn"], h), nc

        if caches is None:
            def body(c, xs):
                lp, ckv = xs
                x, nc = layer(c, lp, ckv, None)
                return x, nc
        else:
            def body(c, xs):
                lp, ckv, cache = xs
                x, nc = layer(c, lp, ckv, cache)
                return x, nc

        if rt.remat == "block":
            body = jax.checkpoint(body)
        xs = ((p["dec_layers"], cross_kv) if caches is None
              else (p["dec_layers"], cross_kv, caches))
        x, ncs = jax.lax.scan(body, x, xs,
                              unroll=cfg.num_layers if rt.unroll_layers else 1)
        return layer_norm(x, p["dec_ln"]["w"], p["dec_ln"]["b"]), ncs

    def _embed_tokens(self, p, tokens, pos0=0):
        x = p["embed"][tokens].astype(self.rt.compute_dtype)
        # dynamic_slice so pos0 may be a traced offset (slot admission)
        pos = jax.lax.dynamic_slice_in_dim(
            p["dec_pos"], jnp.asarray(pos0, jnp.int32), tokens.shape[1])
        return constrain(x + pos.astype(x.dtype), "dp", None, None)

    def loss(self, p: Params, batch: Dict[str, jax.Array]):
        cfg = self.cfg
        enc_out = self.encode(p, batch["frames"])
        cross_kv = self._cross_kv(p, enc_out)
        x = self._embed_tokens(p, batch["tokens"], 0)
        x, _ = self._decoder(p, x, cross_kv)
        w = p["embed"].T
        logits = constrain(jnp.einsum("bsd,dv->bsv", x, w),
                           "dp", None, "tp")
        loss = softmax_xent(logits, batch["labels"], cfg.vocab_size)
        return loss, {"xent": loss}

    def prefill(self, p: Params, batch: Dict[str, jax.Array], pos0=0):
        enc_out = self.encode(p, batch["frames"])
        cross_kv = self._cross_kv(p, enc_out)
        x = self._embed_tokens(p, batch["tokens"], pos0)
        x, self_kv = self._decoder(p, x, cross_kv, return_caches=True)
        logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], p["embed"].T)
        return logits, {"self": self_kv, "cross": cross_kv}

    def init_cache(self, batch: int, max_len: int):
        cfg, rt = self.cfg, self.rt
        dh = cfg.resolved_head_dim
        h = pad_heads(cfg.num_heads, rt.tp_degree)
        L = cfg.num_layers
        se = cfg.encoder.max_source_len
        z = lambda *shape: jnp.zeros(shape, rt.compute_dtype)
        return {"self": (z(L, batch, max_len, h, dh),
                         z(L, batch, max_len, h, dh)),
                "cross": (z(L, batch, se, h, dh), z(L, batch, se, h, dh))}

    def decode_step(self, p: Params, caches, token: jax.Array,
                    cache_index: jax.Array):
        x = p["embed"][token].astype(self.rt.compute_dtype)
        pos = jax.lax.dynamic_slice_in_dim(p["dec_pos"], cache_index, 1)
        x = x + pos.astype(x.dtype)[None]
        x, ncs = self._decoder(p, x, caches["cross"], caches=caches["self"],
                               cache_index=cache_index)
        logits = jnp.einsum("bsd,dv->bsv", x, p["embed"].T)
        return logits[:, 0], {"self": ncs, "cross": caches["cross"]}

    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        se = cfg.encoder.max_source_len
        frames = jax.ShapeDtypeStruct((b, se, cfg.d_model),
                                      self.rt.compute_dtype)
        if shape.step in (StepKind.TRAIN, StepKind.PREFILL):
            specs = {"frames": frames,
                     "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
            if shape.step == StepKind.TRAIN:
                specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
            return specs
        return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "cache_index": jax.ShapeDtypeStruct((), jnp.int32)}
