"""k-means alternative to SL-range binning (paper §VII-C).

The paper clusters iteration *execution profiles* with k-means and finds the
simple binning performs as well (runtime is a good proxy for the profile).
We implement Lloyd's algorithm over feature vectors (default: normalized
[SL, runtime]; optionally full stat vectors) and pick each cluster's medoid
as the representative, weighted by cluster population.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core.profile import EpochLog
from repro.core.seqpoint import SeqPoint, SeqPointSet


def _kmeans(x: np.ndarray, k: int, iters: int = 50,
            seed: int = 0) -> np.ndarray:
    rng = np.random.RandomState(seed)
    # k-means++ init
    centers = [x[rng.randint(len(x))]]
    for _ in range(k - 1):
        d2 = np.min(
            [((x - c) ** 2).sum(axis=1) for c in centers], axis=0)
        p = d2 / max(d2.sum(), 1e-12)
        centers.append(x[rng.choice(len(x), p=p)])
    c = np.stack(centers)
    for _ in range(iters):
        assign = np.argmin(
            ((x[:, None] - c[None]) ** 2).sum(-1), axis=1)
        newc = np.stack([
            x[assign == j].mean(axis=0) if (assign == j).any() else c[j]
            for j in range(k)])
        if np.allclose(newc, c):
            break
        c = newc
    return np.argmin(((x[:, None] - c[None]) ** 2).sum(-1), axis=1)


def kmeans_seqpoints(log: EpochLog, k: int = 8, *,
                     stat_keys: Optional[List[str]] = None,
                     seed: int = 0) -> SeqPointSet:
    table = log.by_seq_len()
    feats = [table.seq_lens.astype(float), table.runtimes]
    if stat_keys:
        per_sl = {}
        for it in log.iterations:
            per_sl.setdefault(it.seq_len, []).append(
                [it.stats.get(s, 0.0) for s in stat_keys])
        extra = np.array([np.mean(per_sl[int(s)], axis=0)
                          for s in table.seq_lens])
        feats.extend(extra.T)
    x = np.stack(feats, axis=1)
    x = (x - x.mean(0)) / np.maximum(x.std(0), 1e-12)

    k = min(k, table.num_unique)
    assign = _kmeans(x, k, seed=seed)
    points: List[SeqPoint] = []
    for j in range(k):
        mask = assign == j
        if not mask.any():
            continue
        counts = table.counts[mask]
        runtimes = table.runtimes[mask]
        sls = table.seq_lens[mask]
        center = x[mask].mean(axis=0)
        medoid = int(np.argmin(((x[mask] - center) ** 2).sum(-1)))
        points.append(SeqPoint(int(sls[medoid]), float(counts.sum()),
                               float(runtimes[medoid])))
    pred = float(sum(p.weight * p.runtime for p in points))
    actual = table.total_runtime
    return SeqPointSet(points, k=k, predicted=pred, actual=actual,
                       error=abs(pred - actual) / max(actual, 1e-12),
                       method="kmeans")
