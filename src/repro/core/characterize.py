"""Characterizer: SeqPoint-driven epoch characterization (DESIGN.md §2).

Two profiling backends feed the same selection/projection machinery:

* ``WallclockProvider`` — really executes jitted steps per unique SL on this
  host (the paper's native-hardware profiling). Per-SL XLA compilation is the
  'autotune' analog: excluded from iteration cost, *measured* as profiling
  cost — it is exactly what SeqPoint amortizes (paper §IV-C2 / §VI-F).
* ``CompiledCostProvider`` — ``jit(...).lower().compile().cost_analysis()``
  per SL; an analytic machine model (TPU v5e + paper-analog configs #2-#5)
  turns FLOPs/bytes into per-iteration seconds. This scales the paper's
  hardware-config sensitivity study (Table II) to machines we don't have.

The reproduction experiments (benchmarks/) select SeqPoints ONCE on config#1
and re-profile only those SLs on other configs — the paper's
architecture-independence claim, measured end to end.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.core.profile import EpochLog, SLTable
from repro.core.seqpoint import SeqPointSet, select_seqpoints
from repro.data.batching import BatchPlan
from repro.perfmodel.machine import MachineConfig


@dataclass
class ProfileResult:
    runtime: float                       # per-iteration seconds
    stats: Dict[str, float] = field(default_factory=dict)
    profile_cost: float = 0.0            # seconds spent profiling this SL


class WallclockProvider:
    """Measure real per-iteration wallclock for a (model, batch) at a given
    padded SL. ``step_builder(sl) -> (fn, args)`` returns a jittable step and
    its inputs."""

    def __init__(self, step_builder: Callable[[int], Tuple[Callable, tuple]],
                 repeats: int = 3):
        self.step_builder = step_builder
        self.repeats = repeats
        self.cache: Dict[int, ProfileResult] = {}

    def profile(self, sl: int) -> ProfileResult:
        if sl in self.cache:
            obs.metrics.counter("profile_cache_hits_total",
                                provider="wallclock").inc()
            return self.cache[sl]
        import jax
        with obs.span("profile/wallclock", sl=sl):
            t0 = time.perf_counter()
            with obs.span("profile/compile_warmup", sl=sl):
                fn, args = self.step_builder(sl)
                jfn = jax.jit(fn)
                out = jfn(*args)
                jax.block_until_ready(out)            # compile + warmup
            compile_cost = time.perf_counter() - t0
            times = []
            for _ in range(self.repeats):
                t0 = time.perf_counter()
                with obs.span("profile/measure", sl=sl):
                    jax.block_until_ready(jfn(*args))
                times.append(time.perf_counter() - t0)
        res = ProfileResult(runtime=float(np.median(times)),
                            stats={"runtime_std": float(np.std(times))},
                            profile_cost=compile_cost + sum(times))
        mreg = obs.metrics
        mreg.histogram("profile_step_time_s", sl=sl).observe(res.runtime)
        mreg.histogram("profile_cost_s", provider="wallclock",
                       sl=sl).observe(res.profile_cost)
        self.cache[sl] = res
        return res


class CompiledCostProvider:
    """Per-SL compiled cost analysis -> machine-model seconds."""

    def __init__(self, lower_builder: Callable[[int], "jax.stages.Lowered"],
                 machine: MachineConfig, overlap: bool = True):
        self.lower_builder = lower_builder
        self.machine = machine
        self.overlap = overlap
        self.cost_cache: Dict[int, Tuple[float, float, float]] = {}
        self.profile_costs: Dict[int, float] = {}

    def costs(self, sl: int) -> Tuple[float, float, float]:
        if sl not in self.cost_cache:
            t0 = time.perf_counter()
            with obs.span("profile/compiled_cost", sl=sl):
                compiled = self.lower_builder(sl).compile()
                ca = compiled.cost_analysis()
            flops = float(ca.get("flops", 0.0))
            bts = float(ca.get("bytes accessed", 0.0))
            try:
                from repro.perfmodel.hlo import parse_collectives
                coll = parse_collectives(compiled.as_text()).wire_bytes
            except Exception:
                coll = 0.0
            self.cost_cache[sl] = (flops, bts, coll)
            self.profile_costs[sl] = time.perf_counter() - t0
            obs.metrics.histogram("profile_cost_s", provider="compiled",
                                  sl=sl).observe(self.profile_costs[sl])
        else:
            obs.metrics.counter("profile_cache_hits_total",
                                provider="compiled").inc()
        return self.cost_cache[sl]

    def profile(self, sl: int,
                machine: Optional[MachineConfig] = None) -> ProfileResult:
        flops, bts, coll = self.costs(sl)
        m = machine or self.machine
        t = (m.step_time(flops, bts, coll) if self.overlap
             else m.step_time_sum(flops, bts, coll))
        return ProfileResult(runtime=t,
                             stats={"flops": flops, "bytes": bts,
                                    "coll_bytes": coll},
                             profile_cost=self.profile_costs.get(sl, 0.0))


# ---------------------------------------------------------------------------


def epoch_log_from_plan(plan: BatchPlan, provider,
                        machine: Optional[MachineConfig] = None) -> EpochLog:
    """Profile every unique SL in the plan, build the full epoch log (the
    paper's step (1): this is the expensive ground-truth pass)."""
    log = EpochLog(meta={"batch_size": plan.batch_size})
    uniq = sorted(set(int(s) for s in plan.padded_sls))
    results = {}
    for sl in uniq:
        results[sl] = (provider.profile(sl, machine)
                       if machine is not None else provider.profile(sl))
    for sl in plan.padded_sls:
        r = results[int(sl)]
        log.append(int(sl), r.runtime, **r.stats)
    return log


def project_on_config(points: SeqPointSet, provider,
                      machine: Optional[MachineConfig] = None,
                      kind: str = "total") -> float:
    """Profile ONLY the SeqPoint SLs on a (new) config and project (Eq. 1)."""
    def stat(sl: int) -> float:
        r = (provider.profile(sl, machine) if machine is not None
             else provider.profile(sl))
        return r.runtime
    return (points.project_total(stat) if kind == "total"
            else points.project_mean(stat))


def profiling_cost(provider, sls: List[int]) -> float:
    """Seconds spent profiling the given SLs (compile + measure)."""
    total = 0.0
    for sl in sls:
        if hasattr(provider, "cache") and sl in provider.cache:
            total += provider.cache[sl].profile_cost
        elif hasattr(provider, "profile_costs"):
            total += provider.profile_costs.get(sl, 0.0)
    return total
