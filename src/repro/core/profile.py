"""Iteration execution profiles and epoch logs (paper §IV).

An ``EpochLog`` is the artifact of step (1) of the SeqPoint mechanism: one
training epoch's per-iteration (sequence length, runtime, optional stats).
Stats can carry anything that varies with SL — wallclock seconds, analytic
machine-model seconds, HLO FLOPs/bytes, a kernel-category histogram — the
selection algorithm only assumes "runtime" is a good proxy for the profile
(paper §V-C / §VII-C).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class IterationRecord:
    seq_len: int
    runtime: float
    stats: Mapping[str, float] = field(default_factory=dict)


@dataclass
class EpochLog:
    """Per-iteration log of one training epoch."""

    iterations: List[IterationRecord] = field(default_factory=list)
    meta: Dict[str, object] = field(default_factory=dict)

    def append(self, seq_len: int, runtime: float, **stats: float) -> None:
        self.iterations.append(IterationRecord(int(seq_len), float(runtime),
                                               dict(stats)))

    # ------------------------------------------------------------------
    @property
    def num_iterations(self) -> int:
        return len(self.iterations)

    @property
    def total_runtime(self) -> float:
        return float(sum(it.runtime for it in self.iterations))

    def total_stat(self, key: str) -> float:
        return float(sum(it.stats.get(key, 0.0) for it in self.iterations))

    def seq_lens(self) -> np.ndarray:
        return np.array([it.seq_len for it in self.iterations], dtype=np.int64)

    def runtimes(self) -> np.ndarray:
        return np.array([it.runtime for it in self.iterations])

    # ------------------------------------------------------------------
    def to_jsonable(self) -> dict:
        """Pure-JSON form (checkpoint manifests carry the partial log so a
        crash-resumed run re-extends the epoch bit-for-bit)."""
        return {"meta": dict(self.meta),
                "iterations": [[int(it.seq_len), float(it.runtime),
                                {k: float(v) for k, v in it.stats.items()}]
                               for it in self.iterations]}

    @classmethod
    def from_jsonable(cls, obj: Mapping) -> "EpochLog":
        log = cls(meta=dict(obj.get("meta", {})))
        for sl, rt, stats in obj.get("iterations", []):
            log.append(int(sl), float(rt), **stats)
        return log

    # ------------------------------------------------------------------
    def by_seq_len(self) -> "SLTable":
        """Aggregate to unique SLs (paper key obs. 5: iterations of one SL
        behave the same; we average out measurement noise)."""
        sls: Dict[int, List[IterationRecord]] = {}
        for it in self.iterations:
            sls.setdefault(it.seq_len, []).append(it)
        uniq = sorted(sls)
        counts = np.array([len(sls[s]) for s in uniq], dtype=np.int64)
        runtimes = np.array([np.mean([it.runtime for it in sls[s]])
                             for s in uniq])
        return SLTable(seq_lens=np.array(uniq, dtype=np.int64),
                       counts=counts, runtimes=runtimes)


@dataclass
class SLTable:
    """Unique sequence lengths with iteration counts and mean runtimes."""

    seq_lens: np.ndarray     # (U,) ascending
    counts: np.ndarray       # (U,) iterations per SL in the epoch
    runtimes: np.ndarray     # (U,) mean per-iteration runtime

    @property
    def num_unique(self) -> int:
        return int(len(self.seq_lens))

    @property
    def num_iterations(self) -> int:
        return int(self.counts.sum())

    @property
    def total_runtime(self) -> float:
        return float((self.counts * self.runtimes).sum())

    def runtime_of(self, sl: int) -> float:
        idx = int(np.searchsorted(self.seq_lens, sl))
        if idx >= len(self.seq_lens) or self.seq_lens[idx] != sl:
            raise KeyError(f"SL {sl} not in table")
        return float(self.runtimes[idx])
