"""SeqPoint alternatives evaluated in the paper (§VI-C).

  frequent — the most frequently occurring SL, projected over all iterations
  median   — the iteration-median SL
  worst    — the single SL with the worst-case projection error (the bound
             on arbitrarily picking one iteration, paper Figs. 11-16)
  prior    — Zhu et al. [IISWC'18]: 50 contiguous iterations after a warmup,
             mean runtime x iteration count

All return ``SeqPointSet`` so the projection machinery (Eq. 1) is shared.
"""
from __future__ import annotations

from typing import List

import numpy as np

from repro.core.profile import EpochLog
from repro.core.seqpoint import SeqPoint, SeqPointSet


def _single(log: EpochLog, sl: int, method: str) -> SeqPointSet:
    table = log.by_seq_len()
    n = table.num_iterations
    rt = table.runtime_of(sl)
    points = [SeqPoint(seq_len=int(sl), weight=float(n), runtime=rt)]
    pred = n * rt
    actual = table.total_runtime
    return SeqPointSet(points, k=1, predicted=pred, actual=actual,
                       error=abs(pred - actual) / max(actual, 1e-12),
                       method=method)


def frequent(log: EpochLog) -> SeqPointSet:
    table = log.by_seq_len()
    sl = int(table.seq_lens[int(np.argmax(table.counts))])
    return _single(log, sl, "frequent")


def median(log: EpochLog) -> SeqPointSet:
    sls = np.sort(log.seq_lens())
    sl = int(sls[len(sls) // 2])
    return _single(log, sl, "median")


def worst(log: EpochLog) -> SeqPointSet:
    """Upper-bounds the error of picking one arbitrary iteration."""
    table = log.by_seq_len()
    n, actual = table.num_iterations, table.total_runtime
    errs = np.abs(n * table.runtimes - actual)
    sl = int(table.seq_lens[int(np.argmax(errs))])
    return _single(log, sl, "worst")


def prior(log: EpochLog, *, num_iters: int = 50,
          warmup: int = 50) -> SeqPointSet:
    """Sampling-based prior work: profile ``num_iters`` contiguous
    iterations after ``warmup`` — whatever SLs happen to be there."""
    its = log.iterations[warmup:warmup + num_iters]
    if not its:
        its = log.iterations[:num_iters]
    n = log.num_iterations
    scale = n / len(its)
    points = [SeqPoint(seq_len=it.seq_len, weight=scale, runtime=it.runtime)
              for it in its]
    pred = float(sum(p.weight * p.runtime for p in points))
    actual = log.total_runtime
    return SeqPointSet(points, k=len(points), predicted=pred, actual=actual,
                       error=abs(pred - actual) / max(actual, 1e-12),
                       method="prior")


ALL_BASELINES = {"frequent": frequent, "median": median, "worst": worst,
                 "prior": prior}
