"""SeqPoint — the paper's contribution (selection + projection + backends)."""
from repro.core.profile import EpochLog, IterationRecord, SLTable
from repro.core.seqpoint import SeqPoint, SeqPointSet, select_seqpoints
from repro.core.baselines import ALL_BASELINES, frequent, median, prior, worst
from repro.core.clustering import kmeans_seqpoints
from repro.core.characterize import (
    CompiledCostProvider,
    WallclockProvider,
    epoch_log_from_plan,
    profiling_cost,
    project_on_config,
)

__all__ = [
    "ALL_BASELINES", "CompiledCostProvider", "EpochLog", "IterationRecord",
    "SLTable", "SeqPoint", "SeqPointSet", "WallclockProvider",
    "epoch_log_from_plan", "frequent", "kmeans_seqpoints", "median", "prior",
    "profiling_cost", "project_on_config", "select_seqpoints", "worst",
]
