"""End-to-end SeqPoint reproduction on the paper's networks (GNMT, DS2).

Two tracks (DESIGN.md §2/§3):

* Track W (wallclock): really run reduced-size GNMT/DS2 training iterations
  per unique padded SL on this host; SeqPoint + all baselines project the
  epoch's total training time (paper Figs. 11/12, config #1).
* Track A (analytic machine configs): per-SL compiled FLOPs/bytes drive the
  five paper-analog hardware configs (Table II); SeqPoints selected on
  config #1 project times and speedups on configs #2-#5 (Figs. 11-16).

Also measured: per-SL profiling cost (XLA compile+measure seconds) — the
quantity SeqPoint reduces by ~two orders of magnitude (paper §VI-F).

Results cache to results/repro_<network>.json; benchmarks/ are thin readers.
"""
from __future__ import annotations

import json
import os
import re
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.baselines import ALL_BASELINES
from repro.core.characterize import (
    CompiledCostProvider,
    WallclockProvider,
    epoch_log_from_plan,
    profiling_cost,
    project_on_config,
)
from repro.core.clustering import kmeans_seqpoints
from repro.core.profile import EpochLog
from repro.core.seqpoint import SeqPointSet, select_seqpoints
from repro.data.batching import plan_epoch
from repro.data.synthetic import IWSLT_LIKE, LIBRISPEECH_LIKE
from repro.perfmodel.machine import PAPER_CONFIGS

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results")


# ---------------------------------------------------------------------------
# network setups


def _gnmt_setup():
    import jax
    from repro.models.rnn import GNMT, GNMTConfig

    cfg = GNMTConfig(vocab_size=2048, d_model=96, num_enc_uni=2, num_dec=2)
    model = GNMT(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def step_builder(sl: int):
        batch = model.make_batch(sl, 16, sl, sl)

        def step(p, b):
            loss, _ = model.loss(p, b)
            grads = jax.grad(lambda pp: model.loss(pp, b)[0])(p)
            return loss, jax.tree.map(lambda x, g: x - 1e-4 * g, p, grads)

        return step, (params, batch)

    return dict(step_builder=step_builder, dist=IWSLT_LIKE, batch_size=64,
                granularity=4, sort_first=False, samples=6400)


def _ds2_setup():
    import jax
    from repro.models.rnn import DS2, DS2Config

    cfg = DS2Config(num_freq=64, conv_channels=8, d_h=64, num_gru=2)
    model = DS2(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def step_builder(sl: int):
        batch = model.make_batch(sl, 8, sl)

        def step(p, b):
            loss, _ = model.loss(p, b)
            grads = jax.grad(lambda pp: model.loss(pp, b)[0])(p)
            return loss, jax.tree.map(lambda x, g: x - 1e-4 * g, p, grads)

        return step, (params, batch)

    # DS2 sorts inputs in the first epoch (paper §VI-D artifact)
    return dict(step_builder=step_builder, dist=LIBRISPEECH_LIKE,
                batch_size=32, granularity=64, sort_first=True, samples=3200)


SETUPS: Dict[str, Callable[[], dict]] = {"gnmt": _gnmt_setup,
                                         "ds2": _ds2_setup}


# ---------------------------------------------------------------------------


def _select_all(log: EpochLog, error_threshold: float
                ) -> Dict[str, SeqPointSet]:
    out = {"seqpoint": select_seqpoints(log,
                                        error_threshold=error_threshold)}
    for name, fn in ALL_BASELINES.items():
        out[name] = fn(log)
    out["kmeans"] = kmeans_seqpoints(log, k=out["seqpoint"].num_points)
    return out


def _hlo_op_histogram(lowered) -> Dict[str, int]:
    """Kernel-distribution analog: compiled HLO ops keyed by (op, shape) —
    the shape carries the SL dependence the paper's Fig. 5/8 sees in CUDA
    kernel selection (op *types* alone are SL-invariant under lax.scan)."""
    txt = lowered.compile().as_text()
    ops = re.findall(
        r"= ([a-z][a-z0-9]*\[[0-9,]*\])[^ ]* ([a-z][a-z0-9-]*)\(", txt)
    from collections import Counter
    return dict(Counter(f"{op}:{shape}" for shape, op in ops))


def run_reproduction(network: str, *, error_threshold: float = 0.02,
                     seed: int = 0, force: bool = False,
                     samples: Optional[int] = None,
                     tag: str = "") -> dict:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, f"repro_{network}{tag}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    import jax
    setup = SETUPS[network]()
    if samples:
        setup["samples"] = samples
    rng = np.random.RandomState(seed)
    sls = setup["dist"].sample(rng, setup["samples"])
    plan = plan_epoch(sls, setup["batch_size"],
                      granularity=setup["granularity"],
                      sort_first=setup["sort_first"], seed=seed)
    uniq = sorted(set(int(s) for s in plan.padded_sls))
    result: dict = {
        "network": network,
        "num_iterations": plan.num_batches,
        "num_unique_sls": len(uniq),
        "unique_sls": uniq,
        "sl_histogram": {int(s): int((plan.padded_sls == s).sum())
                         for s in uniq},
        "padding_waste": plan.padding_waste(),
    }

    # ---- Track W: wallclock ------------------------------------------------
    wall = WallclockProvider(setup["step_builder"], repeats=3)
    t0 = time.perf_counter()
    log_w = epoch_log_from_plan(plan, wall)
    full_profile_seconds = time.perf_counter() - t0
    sel_w = _select_all(log_w, error_threshold)
    result["wallclock"] = {
        "total_epoch_seconds": log_w.total_runtime,
        "runtime_by_sl": {int(s): wall.cache[s].runtime for s in uniq},
        "methods": {
            name: {"num_points": s.num_points, "k": s.k,
                   "predicted": s.predicted, "actual": s.actual,
                   "error_pct": 100 * s.error,
                   "seq_lens": s.seq_lens}
            for name, s in sel_w.items()},
        "profiling": {
            "full_seconds": full_profile_seconds,
            "seqpoint_seconds": profiling_cost(
                wall, sel_w["seqpoint"].seq_lens),
            "iterations_full": plan.num_batches,
            "iterations_seqpoint": sel_w["seqpoint"].num_points,
            "iter_reduction": plan.num_batches
            / max(sel_w["seqpoint"].num_points, 1),
        },
    }

    # ---- Track A: five machine configs ------------------------------------
    def lower_builder(sl: int):
        fn, args = setup["step_builder"](sl)
        return jax.jit(fn).lower(*args)

    # no-overlap (sum) execution model: per-SL arithmetic intensity then
    # shapes each hardware config's speedup, as on the paper's real GPU
    # (with the max/roofline model every SL is compute-bound and the
    # sensitivity study degenerates)
    prov = CompiledCostProvider(lower_builder, PAPER_CONFIGS["config1"],
                                overlap=False)
    logs = {c: epoch_log_from_plan(plan, prov, machine=m)
            for c, m in PAPER_CONFIGS.items()}
    sel_a = _select_all(logs["config1"], error_threshold)
    actual = {c: logs[c].total_runtime for c in PAPER_CONFIGS}
    track_a = {"actual_seconds": actual, "methods": {}}
    for name, points in sel_a.items():
        per_cfg = {}
        for c, m in PAPER_CONFIGS.items():
            pred = project_on_config(points, prov, machine=m)
            err = abs(pred - actual[c]) / actual[c] * 100
            # speedup (throughput uplift vs config1), paper Figs. 15/16
            pred1 = project_on_config(points, prov,
                                      machine=PAPER_CONFIGS["config1"])
            sp_actual = actual["config1"] / actual[c]
            sp_pred = pred1 / pred
            per_cfg[c] = {"time_error_pct": err,
                          "speedup_actual": sp_actual,
                          "speedup_pred": sp_pred,
                          "speedup_error_pp": 100 * abs(sp_pred - sp_actual)
                          / sp_actual}
        geo = float(np.exp(np.mean([np.log(max(v["time_error_pct"], 1e-3))
                                    for v in per_cfg.values()])))
        track_a["methods"][name] = {"per_config": per_cfg,
                                    "geomean_time_error_pct": geo,
                                    "num_points": points.num_points}
    # per-SL sensitivity (Figs. 13/14): speedup of each SL, config1 -> c
    sens = {}
    for c, m in PAPER_CONFIGS.items():
        if c == "config1":
            continue
        sens[c] = {int(sl): prov.profile(sl, PAPER_CONFIGS["config1"]).runtime
                   / prov.profile(sl, m).runtime for sl in uniq}
    track_a["per_sl_speedup"] = sens
    track_a["per_sl_stats"] = {
        int(sl): dict(prov.profile(sl).stats) for sl in uniq}
    result["analytic"] = track_a

    # ---- Fig. 8 analog: HLO op histograms for nearby/far SLs ---------------
    if len(uniq) >= 4:
        picks = [uniq[0], uniq[1], uniq[len(uniq) // 2], uniq[-1]]
        hist = {int(sl): _hlo_op_histogram(lower_builder(sl)) for sl in picks}
        result["op_histograms"] = hist

    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    return result
