"""The SeqPoint selection mechanism (paper §V-C, Fig. 10).

  (1) log one epoch's unique SLs + iteration runtimes  ->  EpochLog/SLTable
  (2) bin SLs into k contiguous ranges (k=5 initially)
  (3) representative per bin: the SL whose mean runtime is closest to the
      bin's (iteration-weighted) average runtime
  (4) weight := number of iterations in the bin
  (5) predicted epoch statistic := sum_i w_i * s_i        (paper Eq. 1)
  (6) if |predicted - actual| / actual > e: k += 1, goto (2)

If the epoch has at most ``n_threshold`` unique SLs, every unique SL is a
SeqPoint with weight = its frequency (projection is then exact).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profile import EpochLog, SLTable


@dataclass(frozen=True)
class SeqPoint:
    seq_len: int
    weight: float              # iterations represented
    runtime: float             # profiled per-iteration statistic at selection


@dataclass
class SeqPointSet:
    points: List[SeqPoint]
    k: int                     # bins used (0 = all-unique mode)
    predicted: float           # Eq. 1 applied to the selection statistic
    actual: float              # logged epoch total
    error: float               # |predicted-actual|/actual
    method: str = "seqpoint"
    meta: dict = field(default_factory=dict)

    @property
    def seq_lens(self) -> List[int]:
        return [p.seq_len for p in self.points]

    @property
    def weights(self) -> np.ndarray:
        return np.array([p.weight for p in self.points])

    @property
    def num_points(self) -> int:
        return len(self.points)

    # --- paper Eq. 1 -------------------------------------------------------
    def project_total(self, stat: Callable[[int], float]) -> float:
        """Weighted sum of a per-iteration statistic measured only at the
        SeqPoint SLs (e.g. runtime on a *different* hardware config)."""
        return float(sum(p.weight * stat(p.seq_len) for p in self.points))

    def project_mean(self, stat: Callable[[int], float]) -> float:
        """Weight-normalized projection for ratio statistics (paper §V-C:
        throughput, IPC, ...)."""
        w = self.weights.sum()
        return self.project_total(stat) / max(w, 1e-12)


def _bin_edges(table: SLTable, k: int) -> np.ndarray:
    lo, hi = int(table.seq_lens[0]), int(table.seq_lens[-1])
    return np.linspace(lo, hi + 1, k + 1)


def _select_with_k(table: SLTable, k: int) -> List[SeqPoint]:
    edges = _bin_edges(table, k)
    bins = np.clip(np.digitize(table.seq_lens, edges) - 1, 0, k - 1)
    points: List[SeqPoint] = []
    for b in range(k):
        mask = bins == b
        if not mask.any():
            continue
        sls = table.seq_lens[mask]
        counts = table.counts[mask]
        runtimes = table.runtimes[mask]
        # iteration-weighted average runtime of the bin
        avg = float((counts * runtimes).sum() / counts.sum())
        rep = int(np.argmin(np.abs(runtimes - avg)))
        points.append(SeqPoint(seq_len=int(sls[rep]),
                               weight=float(counts.sum()),
                               runtime=float(runtimes[rep])))
    return points


def _eq1(points: Sequence[SeqPoint]) -> float:
    return float(sum(p.weight * p.runtime for p in points))


def select_seqpoints(log: EpochLog | SLTable, *,
                     n_threshold: int = 10,
                     k_init: int = 5,
                     error_threshold: float = 0.02,
                     k_max: int = 64) -> SeqPointSet:
    table = log.by_seq_len() if isinstance(log, EpochLog) else log
    actual = table.total_runtime

    if table.num_unique <= n_threshold:
        points = [SeqPoint(int(s), float(c), float(r))
                  for s, c, r in zip(table.seq_lens, table.counts,
                                     table.runtimes)]
        pred = _eq1(points)
        return SeqPointSet(points, k=0, predicted=pred, actual=actual,
                           error=abs(pred - actual) / max(actual, 1e-12),
                           meta={"mode": "all-unique", "converged": True})

    best: Optional[SeqPointSet] = None
    k = k_init
    while k <= min(k_max, table.num_unique):
        points = _select_with_k(table, k)
        pred = _eq1(points)
        err = abs(pred - actual) / max(actual, 1e-12)
        cand = SeqPointSet(points, k=k, predicted=pred, actual=actual,
                           error=err,
                           meta={"mode": "binned", "converged": True})
        if best is None or err < best.error:
            best = cand
        if err <= error_threshold:
            return cand
        k += 1
    assert best is not None
    best.meta["converged"] = False
    return best
