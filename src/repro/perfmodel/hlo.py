"""Parse collective traffic out of compiled HLO text.

``cost_analysis`` has no collective-bytes entry, so we sum operand/result
sizes of every collective op in the (SPMD, per-device) module and convert to
on-the-wire bytes with standard ring-algorithm factors (DESIGN.md §6).
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result = <shape> <op>(<operand shapes ...>)
_OP_RE = re.compile(
    r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# wire-bytes factor per buffer byte (ring algorithms, large k limit)
_WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


@dataclass
class CollectiveStats:
    count: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    buffer_bytes: Dict[str, int] = field(
        default_factory=lambda: defaultdict(int))

    @property
    def wire_bytes(self) -> float:
        return sum(_WIRE_FACTOR[k] * v for k, v in self.buffer_bytes.items())

    @property
    def total_count(self) -> int:
        return sum(self.count.values())

    def scaled(self, factor: float) -> "CollectiveStats":
        out = CollectiveStats()
        for k in self.count:
            out.count[k] = int(self.count[k] * factor)
            out.buffer_bytes[k] = int(self.buffer_bytes[k] * factor)
        return out

    def minus(self, other: "CollectiveStats") -> "CollectiveStats":
        out = CollectiveStats()
        for k in set(self.count) | set(other.count):
            out.count[k] = self.count[k] - other.count[k]
            out.buffer_bytes[k] = (self.buffer_bytes[k]
                                   - other.buffer_bytes[k])
        return out

    def plus(self, other: "CollectiveStats") -> "CollectiveStats":
        out = CollectiveStats()
        for k in set(self.count) | set(other.count):
            out.count[k] = self.count[k] + other.count[k]
            out.buffer_bytes[k] = (self.buffer_bytes[k]
                                   + other.buffer_bytes[k])
        return out

    def wire_bytes_of(self, kinds) -> float:
        """Wire bytes restricted to the given collective kinds."""
        return sum(_WIRE_FACTOR[k] * self.buffer_bytes.get(k, 0)
                   for k in kinds)

    def to_dict(self) -> Dict[str, Dict[str, int]]:
        return {k: {"count": self.count[k], "bytes": self.buffer_bytes[k]}
                for k in sorted(self.count)}

    @classmethod
    def from_dict(cls, d: Dict[str, Dict[str, int]]) -> "CollectiveStats":
        out = cls()
        for k, v in d.items():
            out.count[k] = int(v.get("count", 0))
            out.buffer_bytes[k] = int(v.get("bytes", 0))
        return out


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device buffer bytes for each collective kind.

    For all-gather we count the *result* shape (what lands per device); for
    the others the result ~= operand. ``-done`` ops are skipped so async
    pairs are counted once.
    """
    stats = CollectiveStats()
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        if kind == "reduce-scatter":
            # result is the scattered shard; wire bytes ~ full operand
            # (approximate: result * k; we lack k here, use operand from
            # the argument list if parsable)
            tail = hlo_text[m.end():m.end() + 400]
            ms = _SHAPE_RE.search(tail)
            if ms:
                b = _shape_bytes(ms.group(0))
        stats.count[kind] += 1
        stats.buffer_bytes[kind] += b
    return stats
