"""Analytic MODEL_FLOPS = 6·N·D (train) / 2·N·D (serve), N = active params.

Counted from the config (not the compiled module) so the
MODEL_FLOPS/HLO_FLOPS ratio exposes remat recompute, padding waste, causal
flash waste, etc. Attention S^2 FLOPs are *excluded* by convention; for
long-context cells the gap is reported as attention share (EXPERIMENTS.md).
"""
from __future__ import annotations

from repro.configs.base import BlockKind as BK
from repro.configs.base import ModelConfig, ShapeConfig, StepKind
from repro.models.layers import padded_vocab


def _block_params(cfg: ModelConfig, kinds, active: bool) -> int:
    mixer, ffn = kinds
    d, dh = cfg.d_model, cfg.resolved_head_dim
    n = 0
    if mixer == BK.ATTENTION:
        hq, hkv = cfg.num_heads, cfg.num_kv_heads
        n += d * hq * dh * 2 + d * hkv * dh * 2
    elif mixer == BK.MLA:
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        n += (d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk
              + d * m.kv_lora_rank + d * m.qk_rope_head_dim
              + m.kv_lora_rank * cfg.num_heads
              * (m.qk_nope_head_dim + m.v_head_dim)
              + cfg.num_heads * m.v_head_dim * d)
    elif mixer == BK.MAMBA:
        di = cfg.mamba.expand * d
        dtr = max(d // 16, 8)
        n += (d * 2 * di + cfg.mamba.d_conv * di
              + di * (dtr + 2 * cfg.mamba.d_state) + dtr * di + di * d)
    elif mixer == BK.RWKV:
        da = (d // cfg.rwkv_head_dim) * cfg.rwkv_head_dim
        n += 5 * d * da + 64 * (d + da)
    if ffn == BK.DENSE_FFN:
        n += 3 * d * cfg.d_ff
    elif ffn == BK.MOE_FFN:
        m = cfg.moe
        f = m.expert_d_ff or cfg.d_ff
        per_expert = 3 * d * f
        if active:
            n += per_expert * m.experts_per_token
        else:
            n += per_expert * m.num_experts
        n += per_expert * m.num_shared_experts + d * m.num_experts
    elif ffn == BK.RWKV_CHANNEL:
        n += 2 * d * cfg.d_ff + d * d
    return n


def param_count(cfg: ModelConfig, active: bool = False) -> int:
    """Non-embedding params (+ LM head); MoE experts scaled to top-k when
    ``active``."""
    per_period = sum(_block_params(cfg, kinds, active)
                     for kinds in cfg.pattern)
    n = per_period * (cfg.num_layers // cfg.interleave_period)
    if cfg.encoder is not None:
        d = cfg.d_model
        enc_layer = 4 * d * d * (1 if cfg.num_kv_heads == cfg.num_heads
                                 else 1) + 2 * d * cfg.d_ff
        dec_extra = 4 * d * d + 0  # cross-attn
        n = (cfg.encoder.num_layers * enc_layer
             + cfg.num_layers * (enc_layer + dec_extra))
    n += cfg.d_model * padded_vocab(cfg.vocab_size)       # head
    if cfg.mtp_depth:
        n += (_block_params(cfg, cfg.pattern[0], active)
              + 2 * cfg.d_model * cfg.d_model)
    return n


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_active = param_count(cfg, active=True)
    if shape.step == StepKind.TRAIN:
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.step == StepKind.PREFILL:
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # decode: one token
