"""Analytic per-device HBM traffic model (roofline memory term).

XLA-CPU ``cost_analysis()['bytes accessed']`` counts every SSA value on the
*CPU* module — bf16 work is promoted to f32 and elementwise chains that a
TPU compile fuses into matmuls are materialized, inflating apparent traffic
by >10x. The roofline memory term therefore uses this analytic model
(coefficients documented inline; fidelity target +-2x), while the measured
XLA number is kept in the record as ``bytes_xla_cpu`` for transparency.

Model (train, per device, per step):
  weights     nmicro * 3 reads of the TP-resident compute weights
              (fwd + dW + dx passes)
  fsdp        + gather write+read per microbatch per pass when ZeRO-3
  optimizer   p (r+w) + m,v (r+w) + grad accumulator (r+w per microbatch)
  activations ACT_RT round-trips of (B_mic, S, d) per layer per pass-set
              (fwd, recompute, bwd with remat=block)
  attention   flash KV re-streams: ceil(S/BQ) reads of the KV block rows
  logits      (B_mic, S, V/tp) write + read, fp32, per microbatch
Serve steps: weights once + cache traffic + activations once.
"""
from __future__ import annotations

from repro.configs.base import (
    BlockKind as BK,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    StepKind,
)
from repro.models.layers import padded_vocab
from repro.perfmodel.model_flops import param_count

ACT_RT = 8            # activation round-trips per layer per pass
FLASH_BQ = 2048       # q-block rows per KV re-stream


def _bytes_of(dtype: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}[dtype]


def hbm_traffic(run: RunConfig) -> float:
    cfg, shape, mesh = run.model, run.shape, run.mesh
    tp = mesh.model_degree if run.parallelism == "tp" else 1
    dp = mesh.data_degree if run.parallelism == "tp" else mesh.num_devices
    dev = mesh.num_devices
    nmicro = max(run.microbatches, 1)
    pb = _bytes_of(run.param_dtype)
    n_total = param_count(cfg, active=False)
    if run.moe_full_ep:
        # experts fully sharded over (data x model): per-device expert slice
        n_nonexp = param_count(cfg, active=True)
        w_compute = (n_nonexp / tp + (n_total - n_nonexp) / dev) * pb
    else:
        w_compute = n_total * pb / tp             # TP/EP-resident weights
    s, d = shape.seq_len, cfg.d_model
    b_loc = max(shape.global_batch // dp, 1)
    vp = padded_vocab(cfg.vocab_size)

    if shape.step == StepKind.TRAIN:
        b_mic = max(b_loc // nmicro, 1)
        passes = 3                                 # fwd + dW + dx
        t = nmicro * passes * w_compute
        if run.fsdp and run.zero_stage >= 3:
            # ZeRO-3: per-microbatch gather materializes the layer weights
            # (write + read) in fwd and bwd; full-EP expert weights are
            # resident and never gathered
            gatherable = w_compute if not run.moe_full_ep \
                else param_count(cfg, active=True) * pb / tp
            t += nmicro * 2 * 2 * gatherable
        stored = n_total * pb / (tp * (dp if run.fsdp else 1))
        mdt = _bytes_of(run.optimizer.moment_dtype)
        t += 2 * stored                            # p read+write
        t += 4 * (n_total * mdt / (tp * (dp if run.fsdp else 1)))  # m, v r+w
        t += (2 * nmicro + 1) * (n_total * 4 / (tp * (dp if run.fsdp else 1)))
        # activations: fwd + recompute + bwd = 3 pass-sets with remat
        pass_sets = 3 if run.remat != "none" else 2
        t += nmicro * pass_sets * ACT_RT * cfg.num_layers * b_mic * s * d * 2
        if not cfg.attention_free:
            restreams = max(s // FLASH_BQ, 1)
            kvb = b_mic * s * max(cfg.num_kv_heads, 1) \
                * cfg.resolved_head_dim * 2 * 2
            t += nmicro * pass_sets * cfg.num_layers * restreams * kvb / tp
        t += nmicro * 2 * b_mic * s * (vp / tp) * 4        # logits r/w
        return float(t)

    if shape.step == StepKind.PREFILL:
        t = w_compute
        if run.fsdp:
            t += 2 * w_compute
        t += ACT_RT * cfg.num_layers * b_loc * s * d * 2
        if not cfg.attention_free:
            restreams = max(s // FLASH_BQ, 1)
            kvb = b_loc * s * max(cfg.num_kv_heads, 1) \
                * cfg.resolved_head_dim * 2 * 2
            t += cfg.num_layers * restreams * kvb / tp
        return float(t)

    # decode: weights once + full cache read + tiny activations
    t = w_compute
    if run.fsdp:
        t += 2 * w_compute
    if cfg.mla is not None:
        cache = cfg.num_layers * shape.global_batch * s \
            * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * 2
    elif cfg.attention_free:
        dh = cfg.rwkv_head_dim
        cache = cfg.num_layers * shape.global_batch \
            * (d // dh) * dh * dh * 2
    else:
        n_attn = sum(1 for m, _ in cfg.pattern if m == BK.ATTENTION) \
            * (cfg.num_layers // cfg.interleave_period)
        cache = n_attn * shape.global_batch * s * max(cfg.num_kv_heads, 1) \
            * cfg.resolved_head_dim * 2 * 2
        if cfg.mamba is not None:
            n_m = sum(1 for m, _ in cfg.pattern if m == BK.MAMBA) \
                * (cfg.num_layers // cfg.interleave_period)
            cache += n_m * shape.global_batch * cfg.mamba.expand * d \
                * cfg.mamba.d_state * 2
    t += cache / dev
    t += ACT_RT * cfg.num_layers * shape.global_batch * d * 2 / dev
    return float(t)
