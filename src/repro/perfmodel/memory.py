"""Structural (TPU-side) memory estimate per cell.

The CPU backend promotes bf16 elementwise work to f32 and materializes
whole-operand converts, so ``memory_analysis().temp_size`` over-reports what
a TPU compile would allocate (EXPERIMENTS.md §Dry-run notes). This module
gives the analytic per-device estimate the fleet would actually budget:

  params + optimizer state (exact, = argument bytes)
  + remat checkpoints (train):  L x (B/dp/nmicro) x S x d x 2
  + per-layer working set:      attention scores / MoE dispatch buffers
  + KV caches (serve, exact)
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (
    BlockKind as BK,
    MeshConfig,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    StepKind,
)


def structural_memory(run: RunConfig, argument_bytes: int) -> Dict[str, float]:
    cfg, shape, mesh = run.model, run.shape, run.mesh
    dp = mesh.data_degree
    tp = mesh.model_degree
    b_loc = max(shape.global_batch // dp, 1)
    nmicro = max(run.microbatches, 1)
    s = shape.seq_len
    d = cfg.d_model

    ckpt = 0.0
    work = 0.0
    if shape.step == StepKind.TRAIN:
        b_mic = max(b_loc // nmicro, 1)
        ckpt = cfg.num_layers * b_mic * s * d * 2
        # attention score working set (fp32), q-heads sharded over model
        if not cfg.attention_free:
            h_loc = max(cfg.num_heads // tp, 1)
            chunk = min(s, 2048)
            work += b_mic * h_loc * s * chunk * 4 * 2
        # grad accumulators (fp32 shards) are counted in arguments? no —
        # they are temps of the step: params_fp32 / shards
        work += argument_bytes * 0.4          # fp32 grad accum + update temps
    else:
        h_loc = max((cfg.num_heads or 1) // tp, 1)
        work += shape.global_batch // max(dp, 1) * h_loc * s * 4 * 4
    total = argument_bytes + ckpt + work
    return {"ckpt_bytes": ckpt, "working_bytes": work,
            "structural_bytes": total,
            "fits_v5e_16g_structural": bool(total < 16 * 2**30)}
