"""Machine models: the TPU v5e target + paper-analog hardware configs.

The paper evaluates SeqPoint's architecture-independence across five hardware
configs (Table II: GCLK, CU count, L1/L2 caches). Our analogs scale the
analytic machine terms: GCLK/CU -> peak FLOP/s, caches -> effective HBM
bandwidth. The wallclock backend additionally uses *real* CPU-thread configs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class MachineConfig:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    chips: int = 1

    def step_time(self, flops: float, bytes_hbm: float,
                  bytes_coll: float) -> float:
        """Roofline-max execution model (per-device quantities)."""
        return max(flops / self.peak_flops, bytes_hbm / self.hbm_bw,
                   bytes_coll / self.ici_bw)

    def step_time_sum(self, flops: float, bytes_hbm: float,
                      bytes_coll: float) -> float:
        """Pessimistic no-overlap model; brackets the truth with step_time."""
        return (flops / self.peak_flops + bytes_hbm / self.hbm_bw
                + bytes_coll / self.ici_bw)


TPU_V5E = MachineConfig("tpu-v5e", peak_flops=197e12, hbm_bw=819e9,
                        ici_bw=50e9)
TPU_V5E_HBM_GB = 16.0

# Paper Table II analogs (#1 is the reference config).
PAPER_CONFIGS: Dict[str, MachineConfig] = {
    "config1": TPU_V5E,
    # GCLK 1.6 GHz -> 852 MHz: compute scales, memory system unchanged
    "config2": MachineConfig("gclk-0.53x", peak_flops=197e12 * 852 / 1600,
                             hbm_bw=819e9, ici_bw=50e9),
    # 64 CU -> 16 CU analog: quarter the compute units
    "config3": MachineConfig("cores-0.25x", peak_flops=197e12 / 4,
                             hbm_bw=819e9, ici_bw=50e9),
    # L1 off analog: effective bandwidth for reuse-heavy ops drops
    "config4": MachineConfig("l1-off", peak_flops=197e12, hbm_bw=819e9 * 0.6,
                             ici_bw=50e9),
    # L2 off analog: bandwidth-bound everywhere
    "config5": MachineConfig("l2-off", peak_flops=197e12, hbm_bw=819e9 * 0.35,
                             ici_bw=50e9),
}
