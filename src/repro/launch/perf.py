import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb runner: re-derive a cell's roofline terms under an
optimization and record baseline vs optimized (results/perf_<cell>.json).

    python -m repro.launch.perf --cell deepseek-v3-671b/train_4k \
        --opt moe_full_ep --hypothesis "..."
"""
import argparse
import json
import sys
from typing import Any, Dict

from repro.configs import SINGLE_POD, get_model_config, get_shape
from repro.launch.dryrun import RESULTS_DIR, run_cell
from repro.launch.mesh import make_mesh

OPTS: Dict[str, Dict[str, Any]] = {
    "moe_full_ep": {"moe_full_ep": True},
    "dp_only": {"parallelism": "dp_only"},
    "moe_full_ep_serve": {"moe_full_ep": True, "fsdp": False},
    "no_fsdp": {"fsdp": False},
    "nmicro4": {"microbatches": 4},
    "save_boundaries": {"remat": "save_boundaries"},
    "moe_full_ep_zero1": {"moe_full_ep": True, "zero_stage": 1},
}


def terms_of(rec: dict) -> dict:
    t = rec["terms"]
    bound = max(t.values())
    return {"compute_s": t["compute_s"], "memory_s": t["memory_s"],
            "collective_s": t["collective_s"], "bound_s": bound,
            "dominant": rec["dominant"],
            "fraction": rec["roofline_fraction"],
            "useful_flops_ratio": rec["useful_flops_ratio"],
            "collectives": rec["collectives"]}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)         # arch/shape
    ap.add_argument("--opt", required=True, choices=sorted(OPTS))
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--tag", default=None)
    args = ap.parse_args()
    arch, shape_name = args.cell.split("/")

    mesh = make_mesh(SINGLE_POD)
    base = run_cell(arch, shape_name, SINGLE_POD, mesh, "roofline")
    if base["status"] != "ok":
        print("baseline failed:", base.get("error"), file=sys.stderr)
        return 1
    opt = run_cell(arch, shape_name, SINGLE_POD, mesh, "roofline",
                   **OPTS[args.opt])
    if opt["status"] != "ok":
        print("optimized failed:", opt.get("error"), file=sys.stderr)
        print(opt.get("traceback", ""), file=sys.stderr)
        return 1

    b, o = terms_of(base), terms_of(opt)
    out = {
        "cell": f"{arch}-{shape_name}-{args.opt}",
        "arch": arch, "shape": shape_name, "opt": args.opt,
        "hypothesis": args.hypothesis,
        "baseline": b, "optimized": o,
        "speedup": b["bound_s"] / max(o["bound_s"], 1e-12),
        "confirmed": o["bound_s"] < b["bound_s"],
    }
    os.makedirs(RESULTS_DIR, exist_ok=True)
    tag = args.tag or f"{arch}_{shape_name}_{args.opt}"
    path = os.path.join(RESULTS_DIR, f"perf_{tag}.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(json.dumps({k: v for k, v in out.items()
                      if k not in ("baseline", "optimized")}, indent=1))
    print("baseline :", json.dumps({k: round(v, 4) if isinstance(v, float)
                                    else v for k, v in b.items()
                                    if k != "collectives"}))
    print("optimized:", json.dumps({k: round(v, 4) if isinstance(v, float)
                                    else v for k, v in o.items()
                                    if k != "collectives"}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
