import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Modes (DESIGN.md §6):
  compile  — rolled layer scan, both meshes: proves the sharding config is
             coherent, reports memory_analysis (true peak footprint).
  roofline — single-pod, layer stack compiled UNROLLED at 1x and 2x the
             interleave period; per-period costs extrapolate exactly to full
             depth (lax.scan bodies are otherwise counted once by
             cost_analysis).

Usage:
  python -m repro.launch.dryrun --mode compile --mesh both
  python -m repro.launch.dryrun --mode roofline --arch rwkv6-3b --shape train_4k
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import (
    MULTI_POD,
    SINGLE_POD,
    MeshConfig,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    StepKind,
    get_model_config,
    get_shape,
    list_archs,
    shapes_for,
)
from repro.dist.sharding import batch_specs, cache_specs, \
    dp_grad_reduce_elems, param_specs
from repro.launch.mesh import make_mesh
from repro.obs import span
from repro.obs.projection import cell_collective_projection, \
    collective_projection_report
from repro.models.model_zoo import build_model
from repro.models.transformer import Runtime
from repro.perfmodel.hlo import CollectiveStats, parse_collectives
from repro.perfmodel.machine import TPU_V5E, TPU_V5E_HBM_GB
from repro.perfmodel.memory import structural_memory
from repro.perfmodel.traffic import hbm_traffic
from repro.perfmodel.model_flops import model_flops, param_count
from repro.train.train_step import TrainState, build_train_step, \
    init_train_state
from repro.train.optimizer import OptState

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results")


def default_run(cfg: ModelConfig, shape: ShapeConfig,
                mesh: MeshConfig, **overrides) -> RunConfig:
    """Production defaults per cell (DESIGN.md §5/§8)."""
    n_total = param_count(cfg, active=False)
    moment_dtype = "bfloat16" if n_total > 100e9 else "float32"
    is_train = shape.step == StepKind.TRAIN
    dp_degree = (mesh.num_devices
                 if overrides.get("parallelism") == "dp_only"
                 else mesh.data_degree)
    # gradient accumulation keeps backward residuals bounded (production
    # practice; the per-microbatch grad all-reduce overlaps the next
    # microbatch's backward under XLA's latency-hiding scheduler). Pick the
    # smallest power of two keeping per-device remat checkpoints <~4 GB.
    nmicro = overrides.pop("microbatches", 0)
    if not nmicro:
        nmicro = 1
        if is_train:
            # remat checkpoints shard over the batch (dp) axis only
            ckpt_bytes = (cfg.num_layers * shape.global_batch
                          * shape.seq_len * cfg.d_model * 2 / dp_degree)
            target = 4 * 2**30
            while (nmicro < shape.global_batch // dp_degree
                   and ckpt_bytes / nmicro > target):
                nmicro *= 2
    kw: Dict[str, Any] = dict(
        model=cfg, shape=shape, mesh=mesh,
        optimizer=OptimizerConfig(moment_dtype=moment_dtype),
        # >100B archs need ZeRO-style storage sharding even at serving
        fsdp=is_train or n_total > 100e9,
        fsdp_over_pods=n_total > 100e9,
        remat="block" if is_train else "none",
        microbatches=nmicro,
    )
    kw.update(overrides)
    return RunConfig(**kw)


def _runtime(run: RunConfig, roofline: bool, n_periods: int) -> Runtime:
    return Runtime(
        tp_degree=run.mesh.model_degree if run.parallelism == "tp" else 1,
        attn_chunk=run.attn_chunk,
        unroll_layers=roofline,
        attn_unroll=64 if roofline else 1,   # >= max chunk count in use
        remat=run.remat,
        param_dtype=jnp.dtype(run.param_dtype),
        compute_dtype=jnp.dtype(run.compute_dtype),
        moe_full_ep=run.moe_full_ep,
    )


def _reduced(cfg: ModelConfig, k: int) -> ModelConfig:
    """k interleave periods of depth (for roofline extrapolation)."""
    if cfg.encoder is not None:
        return cfg.with_overrides(
            num_layers=k,
            encoder=dataclasses.replace(cfg.encoder, num_layers=k))
    return cfg.with_overrides(num_layers=k * cfg.interleave_period)


def _n_periods(cfg: ModelConfig) -> int:
    if cfg.encoder is not None:
        return cfg.num_layers
    return cfg.num_layers // cfg.interleave_period


def _with_sharding(sds_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda sds, spec: jax.ShapeDtypeStruct(
            sds.shape, sds.dtype, sharding=NamedSharding(mesh, spec)),
        sds_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def lower_cell(cfg: ModelConfig, run: RunConfig, mesh,
               roofline: bool) -> jax.stages.Lowered:
    from repro.dist.axes import set_dp_axes

    set_dp_axes(("pod", "data", "model")
                if run.parallelism == "dp_only" else None)
    model = build_model(cfg, _runtime(run, roofline, _n_periods(cfg)))
    shape = run.shape
    rng = jax.random.PRNGKey(0)

    if shape.step == StepKind.TRAIN:
        state_shape = jax.eval_shape(
            lambda r: init_train_state(model, run, r), rng)
        pspecs = param_specs(state_shape.params, cfg, run.mesh,
                             run.fsdp and run.zero_stage >= 3,
                             run.fsdp_over_pods, run.moe_full_ep,
                             run.parallelism)
        # ZeRO-1: optimizer moments sharded even when params stay resident
        ospecs = param_specs(state_shape.params, cfg, run.mesh, run.fsdp,
                             run.fsdp_over_pods, run.moe_full_ep,
                             run.parallelism)
        # error-feedback residual (grad compression) shards like the
        # optimizer moments: gradient-shaped, per-replica persistent state
        efspecs = ospecs if state_shape.ef is not None else None
        state_specs = TrainState(
            params=pspecs, opt=OptState(step=P(), m=ospecs, v=ospecs),
            ef=efspecs)
        state_sds = _with_sharding(state_shape, state_specs, mesh)
        batch_shape = model.input_specs(shape)
        bspecs = batch_specs(batch_shape, run.mesh, shape, run.parallelism)
        batch_sds = _with_sharding(batch_shape, bspecs, mesh)
        step = build_train_step(model, run)
        out_shardings = (
            jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs,
                         is_leaf=lambda x: isinstance(x, P)), None)
        with mesh:
            return jax.jit(step, out_shardings=out_shardings,
                           donate_argnums=0).lower(state_sds, batch_sds)

    params_shape = jax.eval_shape(model.init, rng)
    pspecs = param_specs(params_shape, cfg, run.mesh, fsdp=run.fsdp,
                         fsdp_over_pods=run.fsdp_over_pods,
                         moe_full_ep=run.moe_full_ep,
                         parallelism=run.parallelism)
    params_sds = _with_sharding(params_shape, pspecs, mesh)

    if shape.step == StepKind.PREFILL:
        batch_shape = model.input_specs(shape)
        bspecs = batch_specs(batch_shape, run.mesh, shape)
        batch_sds = _with_sharding(batch_shape, bspecs, mesh)
        with mesh:
            return jax.jit(model.prefill).lower(params_sds, batch_sds)

    # decode: one token against a seq_len cache
    b = shape.global_batch
    cache_shape = jax.eval_shape(lambda: model.init_cache(b, shape.seq_len))
    cspecs = cache_specs(cache_shape, cfg, run.mesh, shape)
    cache_sds = _with_sharding(cache_shape, cspecs, mesh)
    tok_specs = model.input_specs(shape)
    tspecs = batch_specs(tok_specs, run.mesh, shape)
    tok_sds = _with_sharding(tok_specs, tspecs, mesh)
    with mesh:
        return jax.jit(model.decode_step, donate_argnums=1).lower(
            params_sds, cache_sds, tok_sds["token"], tok_sds["cache_index"])


def _dp_reduce_elems(cfg: ModelConfig, run: RunConfig) -> Optional[float]:
    """Per-device DP-ring gradient elements for the projection's analytic
    dp term, from the cell's real spec tree (None for non-train steps)."""
    if run.shape.step != StepKind.TRAIN:
        return None
    model = build_model(cfg, _runtime(run, False, _n_periods(cfg)))
    state_shape = jax.eval_shape(
        lambda r: init_train_state(model, run, r), jax.random.PRNGKey(0))
    pspecs = param_specs(state_shape.params, cfg, run.mesh,
                         run.fsdp and run.zero_stage >= 3,
                         run.fsdp_over_pods, run.moe_full_ep,
                         run.parallelism)
    return dp_grad_reduce_elems(state_shape.params, pspecs, run.mesh)


def _costs(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # jax<=0.4.x: one entry per program
        ca = ca[0] if ca else {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def run_cell(arch: str, shape_name: str, mesh_cfg: MeshConfig, mesh,
             mode: str, **overrides) -> Dict[str, Any]:
    cfg = get_model_config(arch)
    shape = get_shape(shape_name)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": "x".join(map(str, mesh_cfg.shape)),
                           "mode": mode, "status": "ok"}
    t0 = time.time()
    try:
        if mode == "compile":
            run = default_run(cfg, shape, mesh_cfg, **overrides)
            with span("dryrun/lower", arch=arch, shape=shape_name):
                lowered = lower_cell(cfg, run, mesh, roofline=False)
            with span("dryrun/compile", arch=arch, shape=shape_name):
                compiled = lowered.compile()
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
            live = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                    - ma.alias_size_in_bytes)
            rec["memory"]["live_bytes_per_device"] = int(live)
            rec["memory"]["fits_v5e_16g"] = bool(
                live < TPU_V5E_HBM_GB * 2**30)
            # CPU-backend bf16->f32 promotion inflates temps; also record
            # the analytic TPU-side estimate (perfmodel.memory)
            rec["memory"].update(structural_memory(
                run, int(ma.argument_size_in_bytes)))
            rec.update(_costs(compiled))
            coll_stats = parse_collectives(compiled.as_text())
            rec["collectives"] = coll_stats.to_dict()
            # analytic-vs-measured collective bytes (obs.projection): the
            # projection-error report the ROADMAP asks for, per cell. The
            # rolled scans appear once in the HLO text: one interleave
            # period of layer collectives, one microbatch body of grad
            # reduces.
            rec["projection"] = cell_collective_projection(
                cfg, shape, run, coll_stats,
                layers_counted=cfg.interleave_period, micro_counted=1,
                dp_reduce_elems=_dp_reduce_elems(cfg, run))
        elif mode == "roofline":
            n = _n_periods(cfg)
            full_run = default_run(cfg, shape, mesh_cfg, **overrides)
            n_micro = full_run.microbatches
            # Bilinear extrapolation over (layer periods k, microbatches m):
            # cost(k, m) = C0 + Ck*k + Cm*m + Ckm*k*m, solved from four
            # small unrolled compiles (k, m in {1,2}^2). Captures exactly:
            # per-layer-per-microbatch work (compute + ZeRO-3 gathers) in
            # Ckm, token-proportional per-layer work in Ck, per-microbatch
            # overheads in Cm, optimizer/embed/head in C0.
            points = [(1, 1), (2, 1)]
            if n_micro > 1:
                points += [(1, 2), (2, 2)]
            res = {}
            for k, mcount in points:
                rcfg = _reduced(cfg, k)
                run = default_run(rcfg, shape, mesh_cfg,
                                  **dict(overrides, microbatches=mcount))
                run = dataclasses.replace(run, unroll_layers=1)
                lowered = lower_cell(rcfg, run, mesh, roofline=True)
                compiled = lowered.compile()
                res[(k, mcount)] = dict(_costs(compiled))
                res[(k, mcount)]["coll"] = parse_collectives(
                    compiled.as_text())

            def extrap(metric) -> float:
                c11, c21 = metric(res[(1, 1)]), metric(res[(2, 1)])
                if n_micro == 1:
                    return c11 + (n - 1) * (c21 - c11)
                # exact bilinear: per-microbatch constants (Cm) are NOT
                # multiplied by depth
                c12, c22 = metric(res[(1, 2)]), metric(res[(2, 2)])
                ckm = c22 - c21 - c12 + c11
                ck = c21 - c11 - ckm
                cm = c12 - c11 - ckm
                c0 = c11 - ck - cm - ckm
                return c0 + ck * n + cm * n_micro + ckm * n * n_micro

            flops = extrap(lambda r: r["flops"])
            bytes_ = extrap(lambda r: r["bytes"])
            kinds = set()
            for r in res.values():
                kinds |= set(r["coll"].count)
            coll = CollectiveStats()
            for kind in kinds:
                coll.count[kind] = max(int(extrap(
                    lambda r: r["coll"].count.get(kind, 0))), 0)
                coll.buffer_bytes[kind] = max(int(extrap(
                    lambda r: r["coll"].buffer_bytes.get(kind, 0))), 0)
            rec["flops"] = flops
            # memory term from the analytic traffic model — the CPU-module
            # bytes are promotion/fusion-inflated (perfmodel.traffic doc);
            # both are recorded.
            full_run = default_run(cfg, shape, mesh_cfg, **overrides)
            bytes_model = hbm_traffic(full_run)
            rec["bytes_xla_cpu"] = bytes_
            rec["bytes"] = bytes_model
            rec["collectives"] = coll.to_dict()
            rec["wire_bytes"] = coll.wire_bytes
            rec["projection"] = cell_collective_projection(
                cfg, shape, full_run, coll,
                dp_reduce_elems=_dp_reduce_elems(cfg, full_run))
            mf = model_flops(cfg, shape)
            chips = mesh_cfg.num_devices
            t_comp = flops / TPU_V5E.peak_flops
            t_mem = bytes_model / TPU_V5E.hbm_bw
            t_coll = coll.wire_bytes / TPU_V5E.ici_bw
            rec["terms"] = {"compute_s": t_comp, "memory_s": t_mem,
                            "collective_s": t_coll}
            rec["dominant"] = max(rec["terms"], key=rec["terms"].get)
            rec["model_flops_total"] = mf
            rec["model_flops_per_chip"] = mf / chips
            rec["useful_flops_ratio"] = (mf / chips) / max(flops, 1.0)
            bound = max(t_comp, t_mem, t_coll)
            rec["roofline_fraction"] = (mf / chips / TPU_V5E.peak_flops
                                        ) / max(bound, 1e-12)
        rec["seconds"] = round(time.time() - t0, 1)
    except Exception as e:  # noqa: BLE001 — record, keep sweeping
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        rec["seconds"] = round(time.time() - t0, 1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--mode", default="compile",
                    choices=["compile", "roofline"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [SINGLE_POD], "multi": [MULTI_POD],
              "both": [SINGLE_POD, MULTI_POD]}[args.mesh]

    out_path = args.out or os.path.join(
        RESULTS_DIR, f"dryrun_{args.mode}_{args.mesh}.jsonl")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)

    n_fail = 0
    all_recs = []
    with open(out_path, "w") as f:
        for mesh_cfg in meshes:
            mesh = make_mesh(mesh_cfg)
            for arch in archs:
                cfg = get_model_config(arch)
                shapes = (shapes_for(cfg) if args.shape == "all"
                          else [get_shape(s) for s in args.shape.split(",")])
                for shape in shapes:
                    rec = run_cell(arch, shape.name, mesh_cfg, mesh,
                                   args.mode)
                    line = {k: v for k, v in rec.items() if k != "traceback"}
                    print(json.dumps(line), flush=True)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    all_recs.append(rec)
                    if rec["status"] != "ok":
                        n_fail += 1

    # per-cell projection-error report: analytic wire bytes vs measured HLO
    # collective bytes (obs.projection closes the ROADMAP open item here)
    report = collective_projection_report(all_recs)
    proj_path = out_path[:-len(".jsonl")] + "_projection.json" \
        if out_path.endswith(".jsonl") else out_path + ".projection.json"
    with open(proj_path, "w") as f:
        json.dump(report, f, indent=1)
    print("\nprojection error (analytic vs measured collective bytes):",
          file=sys.stderr)
    for c in report["cells"]:
        print(f"  {c['cell']:48s} analytic={c['analytic_wire_bytes']:.3e} "
              f"measured={c['measured_wire_bytes']:.3e} "
              f"rel_error={c['rel_error']:.3f} "
              f"claimed={c.get('rel_error_claimed', c['rel_error']):.3f}",
              file=sys.stderr)
    print(f"  max_rel_error={report['max_rel_error']:.3f} "
          f"claimed={report['max_rel_error_claimed']:.3f} "
          f"({report['num_cells']} cells) -> {proj_path}", file=sys.stderr)
    print(f"\n{'FAILURES: ' + str(n_fail) if n_fail else 'ALL CELLS OK'}",
          file=sys.stderr)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
