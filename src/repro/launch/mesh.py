"""Production meshes. Functions only — importing this module never touches
jax device state (DESIGN.md §6)."""
from __future__ import annotations

from typing import Optional, Sequence

import jax

from repro.configs.base import MULTI_POD, SINGLE_POD, MeshConfig


def make_mesh(cfg: MeshConfig, devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    devices = list(devices if devices is not None else jax.devices())
    need = cfg.num_devices
    if len(devices) < need:
        raise ValueError(
            f"mesh {cfg.shape} needs {need} devices, have {len(devices)} "
            "(the dry-run launcher sets XLA_FLAGS="
            "--xla_force_host_platform_device_count=512 before importing jax)")
    import numpy as np

    dev_grid = np.asarray(devices[:need]).reshape(cfg.shape)
    return jax.sharding.Mesh(dev_grid, cfg.axes)


def try_make_mesh(cfg: MeshConfig,
                  devices: Optional[Sequence] = None
                  ) -> Optional[jax.sharding.Mesh]:
    """``make_mesh`` that returns ``None`` instead of raising when this
    process does not own enough devices.

    The elastic re-mesh path (``resilience.elastic``) uses this to rebuild
    the mesh over surviving devices where possible and to fall back to
    host placement in single-device simulation runs.
    """
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < cfg.num_devices:
        return None
    return make_mesh(cfg, devices)


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """(16, 16) = (data, model) single pod; (2, 16, 16) = (pod, data, model)
    across two pods. 256 chips/pod (TPU v5e-256 topology)."""
    return make_mesh(MULTI_POD if multi_pod else SINGLE_POD)
