"""Production train launcher.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b \
        --smoke --steps 50 --ckpt-dir /tmp/ck

``--smoke`` uses the structure-preserving reduced config (CPU-runnable);
without it the full assigned config is built (requires the real mesh). The
SL schedule is logged and SeqPoints are reported at the end, so every
training run doubles as a profiling artifact (DESIGN.md §2).
"""
from __future__ import annotations

import argparse
import sys

import numpy as np


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--bucketed", action="store_true",
                    help="SL-bucketed batching (beyond-paper opt)")
    args = ap.parse_args()

    from repro.configs import (
        MeshConfig,
        OptimizerConfig,
        RunConfig,
        ShapeConfig,
        StepKind,
        get_model_config,
        smoke_config,
    )
    from repro.data.batching import DataIterator
    from repro.data.synthetic import lm_documents
    from repro.models import Runtime, build_model
    from repro.train.trainer import Trainer

    cfg = smoke_config(args.arch) if args.smoke \
        else get_model_config(args.arch)
    if cfg.frontend is not None and not args.smoke:
        print("full multimodal configs need the frontend stub inputs; "
              "use --smoke or the dry-run", file=sys.stderr)
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        step=StepKind.TRAIN)
    run = RunConfig(model=cfg, shape=shape,
                    mesh=MeshConfig(shape=(1,), axes=("data",)),
                    optimizer=OptimizerConfig(lr=3e-4, warmup_steps=10),
                    param_dtype="float32", compute_dtype="float32")
    model = build_model(cfg, Runtime.from_run(run))
    data = DataIterator(lm_documents(args.seq), samples_per_epoch=4096,
                        batch_size=args.batch, vocab_size=cfg.vocab_size,
                        granularity=16, bucketed=args.bucketed, seed=0)
    trainer = Trainer(model, run, data, ckpt_dir=args.ckpt_dir,
                      total_steps=args.steps)
    rep = trainer.train(args.steps)
    print(f"arch={cfg.name} steps={rep.steps} "
          f"resumed_from={rep.resumed_from} "
          f"loss {rep.losses[0]:.3f}->{rep.losses[-1]:.3f} "
          f"median_step_ms={1e3*np.median(rep.step_times):.1f}")
    sp = trainer.seqpoints(error_threshold=0.05)
    print(f"seqpoints={sp.num_points} sls={sp.seq_lens} "
          f"error={100*sp.error:.2f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
