"""repro.resilience — fault injection, training guardrails, and
crash-consistent recovery.

``faults`` is the deterministic chaos switchboard (env-driven via
``REPRO_FAULTS``), ``guards`` are the training-health invariants, and
``recovery`` holds retries, skip lists, and the crash-consistency contract
for checkpoint extras. See ``src/repro/resilience/README.md``.
"""
from __future__ import annotations

from repro.resilience import faults
from repro.resilience.faults import (
    FaultError,
    FaultPlan,
    FaultSpec,
    PreemptionFault,
    TransientFault,
)
from repro.resilience.guards import (
    DivergenceDetector,
    DivergenceError,
    GuardViolation,
    NonFiniteLossError,
    StepTimeWatchdog,
    WatchdogVerdict,
    check_finite,
)
from repro.resilience.recovery import (
    RETRYABLE,
    BatchSkipList,
    RecoveryPolicy,
    pack_train_extra,
    retry_with_backoff,
    unpack_train_extra,
)

__all__ = [
    "RETRYABLE", "BatchSkipList", "DivergenceDetector", "DivergenceError",
    "FaultError", "FaultPlan", "FaultSpec", "GuardViolation",
    "NonFiniteLossError", "PreemptionFault", "RecoveryPolicy",
    "StepTimeWatchdog", "TransientFault", "WatchdogVerdict", "check_finite",
    "faults", "pack_train_extra", "retry_with_backoff", "unpack_train_extra",
]
