"""repro.resilience — fault injection, training guardrails, and
crash-consistent recovery.

``faults`` is the deterministic chaos switchboard (env-driven via
``REPRO_FAULTS``), ``guards`` are the training-health invariants,
``recovery`` holds retries, skip lists, and the crash-consistency contract
for checkpoint extras, and ``elastic`` models multi-host failure domains
(peer-loss detection, elastic re-meshing, serve replica health). See
``src/repro/resilience/README.md``.
"""
from __future__ import annotations

from repro.resilience import elastic, faults
from repro.resilience.elastic import (
    ClusterFailure,
    ClusterMonitor,
    FailureDomains,
    PeerHealthTracker,
    PeerLossFault,
    ReplicaSet,
)
from repro.resilience.faults import (
    FaultError,
    FaultPlan,
    FaultSpec,
    PreemptionFault,
    TransientFault,
)
from repro.resilience.guards import (
    DivergenceDetector,
    DivergenceError,
    GuardViolation,
    NonFiniteLossError,
    StepTimeWatchdog,
    WatchdogVerdict,
    check_finite,
)
from repro.resilience.recovery import (
    RETRYABLE,
    BatchSkipList,
    RecoveryPolicy,
    backoff_delay,
    pack_train_extra,
    retry_with_backoff,
    unpack_train_extra,
)

__all__ = [
    "RETRYABLE", "BatchSkipList", "ClusterFailure", "ClusterMonitor",
    "DivergenceDetector", "DivergenceError", "FailureDomains", "FaultError",
    "FaultPlan", "FaultSpec", "GuardViolation", "NonFiniteLossError",
    "PeerHealthTracker", "PeerLossFault", "PreemptionFault", "RecoveryPolicy",
    "ReplicaSet", "StepTimeWatchdog", "TransientFault", "WatchdogVerdict",
    "backoff_delay", "check_finite", "elastic", "faults", "pack_train_extra",
    "retry_with_backoff", "unpack_train_extra",
]
