"""Training guardrails: finiteness checks, divergence detection, and a
per-SL step-time watchdog.

Guards are cheap, synchronous checks on values the trainer already has in
hand (the step loss is materialized anyway for the EpochLog). A violation
raises a ``GuardViolation`` subclass; the trainer's recovery path turns that
into a rollback to the last good checkpoint rather than silently logging a
poisoned iteration into the EpochLog SeqPoint selection depends on.

The watchdog generalizes the trainer's original inline straggler logic: the
baseline for a step is the running median of previous steps *of the same
padded SL* (paper key obs. 5: iterations of one SL behave the same), falling
back to the all-SL median for SLs not seen yet.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np


class GuardViolation(RuntimeError):
    """A training-health invariant failed; the step must not be accepted."""

    def __init__(self, msg: str, *, step: Optional[int] = None):
        super().__init__(msg if step is None else f"step {step}: {msg}")
        self.step = step


class NonFiniteLossError(GuardViolation):
    pass


class DivergenceError(GuardViolation):
    pass


def check_finite(value: float, *, name: str = "loss",
                 step: Optional[int] = None) -> float:
    if not math.isfinite(value):
        raise NonFiniteLossError(f"{name} is {value!r}", step=step)
    return value


class DivergenceDetector:
    """EMA-based loss divergence detector.

    Tracks an exponential moving average of the loss; once warmed up, a loss
    above ``ratio * ema`` is suspicious, and ``patience`` *consecutive*
    suspicious steps raise ``DivergenceError``. Suspicious losses do not
    update the EMA, so a genuine divergence cannot drag the baseline up
    after itself and escape detection.
    """

    def __init__(self, *, ratio: float = 4.0, patience: int = 5,
                 warmup: int = 8, decay: float = 0.9):
        assert ratio > 1.0 and patience >= 1
        self.ratio = ratio
        self.patience = patience
        self.warmup = warmup
        self.decay = decay
        self.reset()

    def reset(self) -> None:
        self.ema: Optional[float] = None
        self.steps_seen = 0
        self.streak = 0

    def update(self, loss: float, *, step: Optional[int] = None) -> None:
        self.steps_seen += 1
        if self.ema is None:
            self.ema = float(loss)
            return
        suspicious = (self.steps_seen > self.warmup
                      and loss > self.ratio * self.ema)
        if suspicious:
            self.streak += 1
            if self.streak >= self.patience:
                raise DivergenceError(
                    f"loss {loss:.4g} > {self.ratio:g}x EMA {self.ema:.4g} "
                    f"for {self.streak} consecutive steps", step=step)
            return
        self.streak = 0
        self.ema = self.decay * self.ema + (1.0 - self.decay) * float(loss)


@dataclass(frozen=True)
class WatchdogVerdict:
    sl: int
    dt: float
    baseline: Optional[float]       # None while no baseline exists yet
    is_straggler: bool


class StepTimeWatchdog:
    """Per-SL running-median step-time baseline with straggler verdicts.

    ``observe`` judges a step against the median of earlier same-SL steps
    (all-SL median as cold-start fallback), then folds it into the
    baselines. On a real fleet a straggler verdict triggers hot-spare
    promotion; here the trainer counts it and emits an obs event.
    """

    def __init__(self, factor: float = 3.0):
        self.factor = factor
        self._by_sl: Dict[int, List[float]] = {}
        self._all: List[float] = []

    def baseline(self, sl: int) -> Optional[float]:
        pool = self._by_sl.get(sl) or self._all
        return float(np.median(pool)) if pool else None

    def observe(self, sl: int, dt: float) -> WatchdogVerdict:
        baseline = self.baseline(sl)
        verdict = WatchdogVerdict(
            sl=sl, dt=dt, baseline=baseline,
            is_straggler=(baseline is not None
                          and dt > self.factor * baseline))
        self._by_sl.setdefault(sl, []).append(dt)
        self._all.append(dt)
        return verdict
