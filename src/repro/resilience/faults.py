"""Seeded, deterministic fault injection for chaos drills.

SeqPoint projects a whole run from a few profiled iterations, so the
projection is only trustworthy if the measured run survives the faults a
real fleet throws at it: flaky data loaders, NaN losses, failing checkpoint
disks, preemptions, stragglers. This module is the single switchboard for
*simulating* those faults deterministically, so a chaos run is exactly
reproducible (same plan + seed => same faults at the same steps).

A plan is a comma-separated spec string, env-driven like ``REPRO_OBS_DIR``:

    REPRO_FAULTS="data_fetch@2,nan_loss@5,preempt@9,decode%0.1:times=2"
    REPRO_FAULTS_SEED=0

Each spec is ``point[@step][%prob][:opt=val]*``:

    point@step          fire when the instrumented point reaches ``step``
    point%prob          fire each call with probability ``prob`` (seeded by
                        (seed, point, call index), so replays are identical)
    :times=N            max firings (default 1 for @step, unlimited for %p)
    :delay=S            magnitude for ``straggler`` / ``peer_slow`` (seconds)
    :host=H             target host/replica for the multi-host points

Instrumented points (see ``resilience/README.md`` for where each lives):

    data_fetch      transient error from the data iterator (retryable)
    nan_loss        corrupts the step loss to NaN (guard -> rollback)
    ckpt_save       transient I/O failure inside the checkpoint writer
    ckpt_restore    transient I/O failure at checkpoint load
    ckpt_corrupt    silently flips bytes in arrays.npz *after* the sha256 is
                    recorded (media corruption; caught at restore-verify)
    preempt         simulated preemption mid-step (PreemptionFault)
    straggler       artificial slowdown added to the measured step time
    decode          transient failure of one serve decode call (retryable)
    peer_loss       host ``:host=H`` stops heartbeating permanently
                    (ClusterMonitor confirms the loss -> elastic re-mesh)
    peer_slow       host/replica ``:host=H`` runs ``:delay=S`` late: a missed
                    heartbeat in the trainer, a per-decode-call slowdown in
                    the serve engine (hedging re-issues the batch)
    mesh_partition  hosts >= ``:host=H`` become unreachable from host 0's
                    side of the partition (all confirmed lost together)

When no plan is installed every hook is a single ``is None`` check, so the
instrumented hot paths cost nothing in production.
"""
from __future__ import annotations

import os
import threading
import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro import obs


class FaultError(RuntimeError):
    """Base class for injected faults."""

    def __init__(self, point: str, index: int):
        super().__init__(f"injected fault at {point!r} (index {index})")
        self.point = point
        self.index = index


class TransientFault(FaultError):
    """A fault that a retry is expected to clear (flaky disk, loader)."""


class PreemptionFault(FaultError):
    """Simulated fleet preemption: the step in flight never completes."""


@dataclass(frozen=True)
class FaultSpec:
    point: str
    step: Optional[int] = None      # fire at this step/call index
    prob: float = 0.0               # else: per-call probability
    times: int = 1                  # max firings; <= 0 means unlimited
    delay: float = 0.05             # straggler/peer_slow magnitude (seconds)
    host: int = 0                   # target host/replica for peer points

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        head, *opts = text.strip().split(":")
        step: Optional[int] = None
        prob = 0.0
        if "@" in head:
            point, s = head.split("@", 1)
            step = int(s)
            times = 1
        elif "%" in head:
            point, p = head.split("%", 1)
            prob = float(p)
            times = 0
        else:
            point, times = head, 1
        kw: Dict[str, float] = {}
        for opt in opts:
            k, v = opt.split("=", 1)
            if k == "times":
                times = int(v)
            elif k == "delay":
                kw["delay"] = float(v)
            elif k == "host":
                kw["host"] = int(v)
            else:
                raise ValueError(f"unknown fault option {k!r} in {text!r}")
        return cls(point=point, step=step, prob=prob, times=times, **kw)


class FaultPlan:
    """A set of FaultSpecs plus per-spec firing counters (thread-safe)."""

    def __init__(self, specs: List[FaultSpec], seed: int = 0):
        self.specs = list(specs)
        self.seed = int(seed)
        self._fired = [0] * len(self.specs)
        self._lock = threading.Lock()

    @classmethod
    def parse(cls, text: str, seed: int = 0) -> "FaultPlan":
        specs = [FaultSpec.parse(t) for t in text.split(",") if t.strip()]
        return cls(specs, seed=seed)

    def __repr__(self) -> str:
        return f"FaultPlan({self.specs!r}, seed={self.seed})"

    def _roll(self, spec: FaultSpec, index: int) -> bool:
        # deterministic per (seed, point, index): identical across replays
        # and across processes, which is what makes chaos runs debuggable
        key = f"{self.seed}:{spec.point}:{index}".encode()
        rng = np.random.RandomState(zlib.crc32(key) & 0x7FFFFFFF)
        return bool(rng.random_sample() < spec.prob)

    def check(self, point: str, index: int) -> Optional[FaultSpec]:
        """Return the spec that fires at (point, index), consuming one of
        its ``times`` budget, or None."""
        with self._lock:
            for i, spec in enumerate(self.specs):
                if spec.point != point:
                    continue
                if spec.times > 0 and self._fired[i] >= spec.times:
                    continue
                hit = (index == spec.step) if spec.step is not None \
                    else self._roll(spec, index)
                if hit:
                    self._fired[i] += 1
                    return spec
        return None


# --------------------------------------------------------------------------
# process-global plan (absent by default: every hook is then a no-op)

_PLAN: Optional[FaultPlan] = None


def install(plan: Optional[FaultPlan]) -> Optional[FaultPlan]:
    """Install (or remove, with None) the global plan; returns the old one."""
    global _PLAN
    prev, _PLAN = _PLAN, plan
    return prev


def current() -> Optional[FaultPlan]:
    return _PLAN


def active() -> bool:
    return _PLAN is not None


def check(point: str, index: int) -> Optional[FaultSpec]:
    plan = _PLAN
    if plan is None:
        return None
    spec = plan.check(point, index)
    if spec is not None:
        obs.metrics.counter("faults_injected_total", point=point).inc()
        obs.event("fault_injected", point=point, index=index,
                  step=spec.step, prob=spec.prob)
    return spec


def fire(point: str, index: int) -> None:
    """Raise the point's fault class if a spec fires (else no-op)."""
    if check(point, index) is not None:
        exc = PreemptionFault if point == "preempt" else TransientFault
        raise exc(point, index)


def corrupt(point: str, index: int, value: float) -> float:
    """Return NaN instead of ``value`` if a spec fires."""
    if check(point, index) is not None:
        return float("nan")
    return value


def delay(point: str, index: int) -> float:
    """Seconds of artificial slowdown to add (0.0 when nothing fires)."""
    spec = check(point, index)
    return float(spec.delay) if spec is not None else 0.0


# opt-in via environment, mirroring REPRO_OBS_DIR: REPRO_FAULTS=<plan spec>
# (+ REPRO_FAULTS_SEED) arms the plan for any entrypoint without code edits.
_env_plan = os.environ.get("REPRO_FAULTS")
if _env_plan:
    install(FaultPlan.parse(
        _env_plan, seed=int(os.environ.get("REPRO_FAULTS_SEED", "0"))))
