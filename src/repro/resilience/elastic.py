"""Multi-host failure domains: peer-loss detection and elastic re-meshing.

A production SQNN run spans many hosts; a single lost peer must not kill the
job and discard the SeqPoint profile. This module models the failure domains
of a ``repro.dist`` mesh (which devices live together on which host), tracks
peer health from heartbeats, and — on a confirmed loss — rebuilds the mesh
over the survivors so training (and its EpochLog) continues.

Pieces, bottom-up:

* ``FailureDomains`` — maps the mesh's ``data`` axis onto simulated hosts
  (each host owns a contiguous slab of data-axis rows spanning the full
  model axis, the standard pod topology). ``surviving_mesh`` shrinks the
  data axis past a set of lost hosts and re-numbers the survivors.
* ``PeerHealthTracker`` — consecutive-missed-heartbeat counters; a host is
  *suspect* after one miss and *confirmed lost* after ``confirm_misses``
  consecutive misses, so one late heartbeat (``peer_slow``) never triggers
  a re-mesh.
* ``ClusterMonitor`` — the trainer's per-step pulse: consumes the
  ``peer_loss`` / ``peer_slow`` / ``mesh_partition`` fault points, feeds
  the tracker, emits ``peer_slow`` / ``peer_lost`` events, and raises
  ``PeerLossFault`` once a loss is confirmed (the trainer's tier-4 re-mesh
  arm catches it).
* ``ReplicaSet`` — serve-side replica health for request hedging: the
  engine picks the healthiest replica as primary and hedges onto the next
  healthiest when a batch runs long.
* ``reshard_state`` — re-derives ``repro.dist.sharding`` specs for the
  shrunken mesh and re-shards a restored ``TrainState`` onto it (a no-op
  placement-wise when the process does not own enough devices — CPU test
  runs — but the spec derivation always runs, so layout bugs surface).

When no fault plan is armed and every host is healthy, ``pulse`` is a
single branch — the train loop pays nothing in production.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.configs.base import MeshConfig, RunConfig
from repro.resilience import faults
from repro.resilience.faults import FaultError


class ClusterFailure(RuntimeError):
    """The cluster cannot continue (no surviving hosts to re-mesh over)."""


class PeerLossFault(FaultError):
    """One or more peers are confirmed lost; the mesh must shrink."""

    def __init__(self, hosts: Iterable[int], tick: int):
        self.hosts = frozenset(int(h) for h in hosts)
        self.tick = int(tick)
        RuntimeError.__init__(
            self, f"peer(s) {sorted(self.hosts)} confirmed lost at tick "
                  f"{self.tick}")
        self.point = "peer_loss"
        self.index = self.tick


# --------------------------------------------------------------------------
# failure-domain model


@dataclass(frozen=True)
class FailureDomains:
    """Hosts as failure domains over a mesh's ``data`` axis.

    Each host owns ``data_extent / num_hosts`` contiguous data-axis rows
    (all model/pod columns), so losing a host removes whole data-parallel
    replicas — the layout elastic DP shrinking assumes.
    """

    mesh: MeshConfig
    num_hosts: int

    def __post_init__(self):
        if self.num_hosts < 1:
            raise ValueError("need at least one host")
        if self.data_extent % self.num_hosts != 0:
            raise ValueError(
                f"data axis extent {self.data_extent} not divisible by "
                f"{self.num_hosts} hosts")

    @classmethod
    def from_mesh(cls, mesh: MeshConfig,
                  num_hosts: Optional[int] = None) -> "FailureDomains":
        """Default: one host per data-axis row (finest failure granularity
        that still shrinks cleanly)."""
        if num_hosts is None:
            try:
                num_hosts = mesh.shape[mesh.axes.index("data")]
            except ValueError:
                num_hosts = 1
        return cls(mesh=mesh, num_hosts=num_hosts)

    # ------------------------------------------------------------------
    @property
    def _data_dim(self) -> Optional[int]:
        return self.mesh.axes.index("data") if "data" in self.mesh.axes \
            else None

    @property
    def data_extent(self) -> int:
        d = self._data_dim
        return self.mesh.shape[d] if d is not None else 1

    @property
    def rows_per_host(self) -> int:
        return self.data_extent // self.num_hosts

    @property
    def devices_per_host(self) -> int:
        return self.mesh.num_devices // self.num_hosts

    @property
    def hosts(self) -> Tuple[int, ...]:
        return tuple(range(self.num_hosts))

    def host_of(self, device: int) -> int:
        """Failure domain of a flat (row-major over ``mesh.shape``) device."""
        d = self._data_dim
        if d is None:
            return 0
        coord = np.unravel_index(int(device), self.mesh.shape)[d]
        return int(coord) // self.rows_per_host

    def devices_of(self, host: int) -> List[int]:
        """Flat device indices owned by ``host`` (row-major order)."""
        grid = np.arange(self.mesh.num_devices).reshape(self.mesh.shape)
        d = self._data_dim
        if d is None:
            return list(range(self.mesh.num_devices)) if host == 0 else []
        lo = host * self.rows_per_host
        sel = [slice(None)] * len(self.mesh.shape)
        sel[d] = slice(lo, lo + self.rows_per_host)
        return [int(x) for x in grid[tuple(sel)].reshape(-1)]

    def surviving_devices(self, lost: Iterable[int]) -> List[int]:
        dead = set(int(h) for h in lost)
        out: List[int] = []
        for h in self.hosts:
            if h not in dead:
                out.extend(self.devices_of(h))
        return out

    def surviving_mesh(self, lost: Iterable[int]
                       ) -> Tuple[MeshConfig, "FailureDomains"]:
        """Shrink the data axis past the lost hosts; survivors re-number.

        Raises ``ClusterFailure`` when nothing survives (or the mesh has no
        data axis to shrink).
        """
        dead = set(int(h) for h in lost) & set(self.hosts)
        survivors = self.num_hosts - len(dead)
        if survivors < 1:
            raise ClusterFailure(
                f"all {self.num_hosts} host(s) lost — nothing to re-mesh")
        if not dead:
            return self.mesh, self
        d = self._data_dim
        if d is None:
            raise ClusterFailure(
                f"mesh {self.mesh.shape} has no data axis to shrink past "
                f"lost host(s) {sorted(dead)}")
        shape = list(self.mesh.shape)
        shape[d] = survivors * self.rows_per_host
        new_mesh = MeshConfig(shape=tuple(shape), axes=self.mesh.axes)
        return new_mesh, FailureDomains(mesh=new_mesh, num_hosts=survivors)


# --------------------------------------------------------------------------
# heartbeat-based peer health


@dataclass(frozen=True)
class HealthVerdict:
    tick: int
    suspect: FrozenSet[int]          # missed < confirm_misses beats
    confirmed_lost: FrozenSet[int]   # missed >= confirm_misses beats


class PeerHealthTracker:
    """Consecutive-missed-heartbeat counters per host.

    ``observe(beats, tick)`` folds one heartbeat interval: hosts absent from
    ``beats`` accrue a miss, hosts present reset to zero. A host is suspect
    from its first miss and confirmed lost after ``confirm_misses``
    consecutive misses — one late beat never evicts a peer.
    """

    def __init__(self, hosts: Iterable[int], *, confirm_misses: int = 2):
        self.confirm_misses = max(1, int(confirm_misses))
        self._missed: Dict[int, int] = {int(h): 0 for h in hosts}

    @property
    def hosts(self) -> Tuple[int, ...]:
        return tuple(sorted(self._missed))

    def forget(self, hosts: Iterable[int]) -> None:
        for h in hosts:
            self._missed.pop(int(h), None)

    def observe(self, beats: Iterable[int], tick: int) -> HealthVerdict:
        beats = set(int(b) for b in beats)
        suspect, lost = set(), set()
        for h in self._missed:
            if h in beats:
                self._missed[h] = 0
                continue
            self._missed[h] += 1
            if self._missed[h] >= self.confirm_misses:
                lost.add(h)
            else:
                suspect.add(h)
        return HealthVerdict(tick=int(tick), suspect=frozenset(suspect),
                             confirmed_lost=frozenset(lost))


# --------------------------------------------------------------------------
# cluster monitor (the trainer's per-step pulse)


class ClusterMonitor:
    """Simulated multi-host cluster: failure domains + peer health, fed by
    the ``peer_loss`` / ``peer_slow`` / ``mesh_partition`` fault points.

    ``pulse(tick)`` is called once per training step. Healthy hosts beat
    every pulse; a host hit by ``peer_loss`` (or on the far side of a
    ``mesh_partition``) never beats again, and one hit by ``peer_slow``
    misses that single beat. Once the tracker confirms a loss the pulse
    raises ``PeerLossFault`` — the trainer's tier-4 re-mesh arm takes over.
    """

    def __init__(self, domains: FailureDomains, *, confirm_misses: int = 2):
        self.domains = domains
        self.confirm_misses = confirm_misses
        self.tracker = PeerHealthTracker(domains.hosts,
                                         confirm_misses=confirm_misses)
        self._dead: set = set()

    @classmethod
    def from_mesh(cls, mesh: MeshConfig, *,
                  num_hosts: Optional[int] = None,
                  confirm_misses: int = 2) -> "ClusterMonitor":
        return cls(FailureDomains.from_mesh(mesh, num_hosts),
                   confirm_misses=confirm_misses)

    # ------------------------------------------------------------------
    @property
    def hosts(self) -> Tuple[int, ...]:
        return self.domains.hosts

    @property
    def healthy_hosts(self) -> Tuple[int, ...]:
        return tuple(h for h in self.hosts if h not in self._dead)

    @property
    def dead_hosts(self) -> FrozenSet[int]:
        return frozenset(self._dead)

    def pulse(self, tick: int) -> None:
        """One heartbeat interval; raises ``PeerLossFault`` on confirmed
        loss. Free when no chaos plan is armed and every host is healthy."""
        if not faults.active() and not self._dead:
            return
        spec = faults.check("peer_loss", tick)
        if spec is not None:
            self._dead.add(int(spec.host))
        spec = faults.check("mesh_partition", tick)
        if spec is not None:
            far = {h for h in self.hosts if h >= int(spec.host)}
            self._dead |= far
            obs.event("mesh_partition", tick=tick, cut=int(spec.host),
                      far_side=sorted(far))
        slow: set = set()
        spec = faults.check("peer_slow", tick)
        if spec is not None and int(spec.host) in set(self.hosts):
            slow.add(int(spec.host))
        beats = set(self.hosts) - self._dead - slow
        verdict = self.tracker.observe(beats, tick)
        for h in sorted(verdict.suspect):
            obs.metrics.counter("cluster_missed_beats_total", host=h).inc()
            obs.event("peer_slow", host=h, tick=tick,
                      delay_s=float(spec.delay) if spec is not None else 0.0)
        obs.metrics.gauge("cluster_healthy_hosts").set(
            len(self.hosts) - len(self._dead))
        if verdict.confirmed_lost:
            raise PeerLossFault(verdict.confirmed_lost, tick)

    def after_loss(self, lost: Iterable[int]) -> "ClusterMonitor":
        """The monitor for the re-meshed cluster: survivors only, counters
        reset (the new mesh starts from a clean bill of health). ``lost``
        is unioned with every host already known dead, so a second failure
        confirmed mid-re-mesh is never resurrected."""
        _, domains = self.domains.surviving_mesh(set(lost) | self._dead)
        return ClusterMonitor(domains, confirm_misses=self.confirm_misses)


# --------------------------------------------------------------------------
# serve-side replica health (request hedging)


class ReplicaSet:
    """Health scores for ``n`` simulated serve replicas.

    The engine takes the healthiest replica as primary for each batch and
    hedges onto the next healthiest; a replica that loses a hedge race gets
    a strike (and is avoided until it behaves), one that wins or completes
    normally works a strike off.
    """

    def __init__(self, n: int):
        if n < 1:
            raise ValueError("need at least one replica")
        self.n = int(n)
        self._strikes = [0] * self.n

    def strikes(self, replica: int) -> int:
        return self._strikes[replica]

    def mark_slow(self, replica: int) -> None:
        self._strikes[replica] += 1

    def mark_ok(self, replica: int) -> None:
        self._strikes[replica] = max(0, self._strikes[replica] - 1)

    def pick_primary(self) -> int:
        return int(np.argmin(self._strikes))

    def pick_hedge(self, exclude: int) -> Optional[int]:
        cands = [(s, r) for r, s in enumerate(self._strikes) if r != exclude]
        return min(cands)[1] if cands else None


# --------------------------------------------------------------------------
# re-sharding a restored TrainState onto the shrunken mesh


def reshard_state(state, run: RunConfig, *,
                  device_ids: Optional[Sequence[int]] = None):
    """Re-derive sharding specs for ``run.mesh`` and re-shard ``state``.

    Returns ``(state, n_sharded_leaves)``. The spec derivation
    (``repro.dist.sharding.param_specs``) always runs — that is where an
    elastic-layout bug would surface — but the physical ``device_put`` only
    happens when this process owns enough devices to build the mesh
    (single-device CPU test runs skip it and keep host placement).
    """
    import jax
    from jax.sharding import NamedSharding

    from repro.dist.sharding import param_specs
    from repro.launch.mesh import try_make_mesh

    specs = param_specs(state.params, run.model, run.mesh, fsdp=run.fsdp,
                        fsdp_over_pods=run.fsdp_over_pods,
                        moe_full_ep=run.moe_full_ep,
                        parallelism=run.parallelism)
    n_sharded = sum(1 for sp in jax.tree.leaves(specs)
                    if any(e is not None for e in tuple(sp)))
    devices = None
    if device_ids is not None:
        avail = jax.devices()
        if max(device_ids, default=-1) < len(avail):
            devices = [avail[i] for i in device_ids]
    mesh = try_make_mesh(run.mesh, devices)
    if mesh is None:
        return state, n_sharded
    params = jax.tree.map(
        lambda p, sp: jax.device_put(p, NamedSharding(mesh, sp)),
        state.params, specs)
    return state._replace(params=params), n_sharded
