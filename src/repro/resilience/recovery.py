"""Recovery mechanics: retry-with-backoff, poison-batch skip lists, and
crash-consistent train-state snapshots.

Three recovery tiers, cheapest first:

1. **Retry** (`retry_with_backoff`) — transient faults (flaky loader,
   hiccuping checkpoint disk, one failed decode) are retried with
   exponential backoff; every retry is an obs event + counter.
2. **Rollback** — a guard violation (NaN/inf loss, divergence) restores the
   last good checkpoint *including* the data-iterator state and the partial
   EpochLog, so the replayed steps re-log identically and SeqPoint
   selection is unaffected by the excursion. A batch that keeps failing
   after rollback (`BatchSkipList`) is declared poison and skipped.
3. **Preemption-safe resume** — a simulated preemption writes an emergency
   checkpoint whose ``extra`` carries the iterator position *of the
   interrupted batch* and the partial EpochLog; the resumed process
   re-fetches that exact batch and continues the log bit-for-bit.

`pack_train_extra` / `unpack_train_extra` define the crash-consistency
contract between the trainer and the checkpoint manifest.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple, TypeVar

from repro import obs
from repro.core.profile import EpochLog
from repro.resilience.faults import TransientFault

T = TypeVar("T")

RETRYABLE = (TransientFault, OSError)


@dataclass(frozen=True)
class RecoveryPolicy:
    """Knobs for the three recovery tiers (one object, threaded through
    trainer and serve engine)."""

    max_retries: int = 3            # per retryable operation
    backoff_base_s: float = 0.02    # first retry delay; doubles per attempt
    backoff_factor: float = 2.0
    max_rollbacks: int = 8          # per train() call; then re-raise
    skip_after_failures: int = 2    # rollbacks on one batch before skipping
    divergence_ratio: float = 4.0   # loss vs EMA (guards.DivergenceDetector)
    divergence_patience: int = 5
    check_grads: bool = True        # guard grad_norm finiteness too


def retry_with_backoff(fn: Callable[[], T], *, retries: int = 3,
                       base_delay: float = 0.02, factor: float = 2.0,
                       retryable: tuple = RETRYABLE,
                       sleep: Callable[[float], None] = time.sleep,
                       label: str = "") -> T:
    """Call ``fn`` until it succeeds or ``retries`` retryable failures.

    Non-retryable exceptions (including ``PreemptionFault``) propagate
    immediately; the last retryable failure is re-raised unchanged.
    """
    attempt = 0
    while True:
        try:
            return fn()
        except retryable as e:                         # noqa: PERF203
            attempt += 1
            if attempt > retries:
                raise
            d = base_delay * (factor ** (attempt - 1))
            obs.metrics.counter("resilience_retries_total",
                                label=label or "unlabeled").inc()
            obs.event("retry", label=label, attempt=attempt,
                      delay_s=d, error=repr(e))
            if d > 0:
                sleep(d)


class BatchSkipList:
    """Failure counts per batch key; a batch that causes ``skip_after``
    rollbacks is poison and gets skipped on the next replay.

    Keys are (epoch, batch_index) — the deterministic identity of a batch in
    the resumable iterator, stable across rollbacks and process restarts
    within one plan.
    """

    def __init__(self, skip_after: int = 2):
        self.skip_after = max(1, int(skip_after))
        self._failures: Dict[Any, int] = {}
        self._skip: set = set()

    def record_failure(self, key: Any) -> bool:
        """Note a rollback caused at ``key``; True once it becomes poison."""
        n = self._failures.get(key, 0) + 1
        self._failures[key] = n
        if n >= self.skip_after:
            self._skip.add(key)
        return key in self._skip

    def should_skip(self, key: Any) -> bool:
        return key in self._skip

    @property
    def poisoned(self) -> set:
        return set(self._skip)


# --------------------------------------------------------------------------
# crash-consistency contract for the checkpoint ``extra`` payload


def pack_train_extra(step: int, data_state: Dict[str, int],
                     epoch_log: EpochLog) -> dict:
    return {"step": int(step), "data_state": dict(data_state),
            "epoch_log": epoch_log.to_jsonable()}


def unpack_train_extra(extra: dict) -> Tuple[int, Optional[Dict[str, int]],
                                             Optional[EpochLog]]:
    step = int(extra["step"])
    data_state = extra.get("data_state")
    log = EpochLog.from_jsonable(extra["epoch_log"]) \
        if "epoch_log" in extra else None
    return step, data_state, log
