"""Recovery mechanics: retry-with-backoff, poison-batch skip lists, and
crash-consistent train-state snapshots.

Four recovery tiers, cheapest first:

1. **Retry** (`retry_with_backoff`) — transient faults (flaky loader,
   hiccuping checkpoint disk, one failed decode) are retried with capped,
   jittered exponential backoff; every retry is an obs event + counter.
   The jitter is deterministic per ``(jitter_seed, label, attempt)`` — N
   replicas retrying the same fault with distinct seeds desynchronize
   (no thundering herd) while any single replica's chaos replay is
   bit-identical.
2. **Rollback** — a guard violation (NaN/inf loss, divergence) restores the
   last good checkpoint *including* the data-iterator state and the partial
   EpochLog, so the replayed steps re-log identically and SeqPoint
   selection is unaffected by the excursion. A batch that keeps failing
   after rollback (`BatchSkipList`) is declared poison and skipped.
3. **Preemption-safe resume** — a simulated preemption writes an emergency
   checkpoint whose ``extra`` carries the iterator position *of the
   interrupted batch*, the partial EpochLog, **and the skip list** (so a
   poison batch stays poison across process restarts); the resumed process
   re-fetches that exact batch and continues the log bit-for-bit.
4. **Elastic re-mesh** (`resilience.elastic` + the trainer's tier-4 arm) —
   a confirmed peer loss checkpoints, shrinks the mesh over the survivors,
   re-shards the restored state, and resumes in-process.

`pack_train_extra` / `unpack_train_extra` define the crash-consistency
contract between the trainer and the checkpoint manifest.
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple, TypeVar

from repro import obs
from repro.core.profile import EpochLog
from repro.resilience.faults import TransientFault

T = TypeVar("T")

RETRYABLE = (TransientFault, OSError)


@dataclass(frozen=True)
class RecoveryPolicy:
    """Knobs for the four recovery tiers (one object, threaded through
    trainer and serve engine)."""

    max_retries: int = 3            # per retryable operation
    backoff_base_s: float = 0.02    # first retry delay; doubles per attempt
    backoff_factor: float = 2.0
    max_delay_s: float = 2.0        # backoff cap (exponential stops here)
    jitter_frac: float = 0.25       # +/- fraction of the delay, seeded
    jitter_seed: int = 0            # per-replica seed decorrelates retries
    max_rollbacks: int = 8          # per train() call; then re-raise
    skip_after_failures: int = 2    # rollbacks on one batch before skipping
    divergence_ratio: float = 4.0   # loss vs EMA (guards.DivergenceDetector)
    divergence_patience: int = 5
    check_grads: bool = True        # guard grad_norm finiteness too
    max_remeshes: int = 2           # tier-4 elastic re-meshes per train()


def backoff_delay(attempt: int, *, base_delay: float = 0.02,
                  factor: float = 2.0, max_delay_s: float = 2.0,
                  jitter_frac: float = 0.25, jitter_seed: int = 0,
                  label: str = "") -> float:
    """Delay before retry ``attempt`` (1-based): capped exponential with
    deterministic seeded jitter.

    The jitter draw is keyed by ``(jitter_seed, label, attempt)`` via the
    same crc32 construction the fault plan uses, so a chaos replay with the
    same seed sleeps identically while replicas with different seeds spread
    over ``[1 - jitter_frac, 1 + jitter_frac] * delay``.
    """
    d = min(base_delay * (factor ** (attempt - 1)), max_delay_s)
    if d > 0.0 and jitter_frac > 0.0:
        key = f"{jitter_seed}:{label}:{attempt}".encode()
        u = (zlib.crc32(key) & 0xFFFFFFFF) / float(0x100000000)  # [0, 1)
        d *= 1.0 + jitter_frac * (2.0 * u - 1.0)
        d = min(d, max_delay_s)
    return d


def retry_with_backoff(fn: Callable[[], T], *, retries: int = 3,
                       base_delay: float = 0.02, factor: float = 2.0,
                       max_delay_s: float = 2.0, jitter_frac: float = 0.25,
                       jitter_seed: int = 0,
                       retryable: tuple = RETRYABLE,
                       sleep: Callable[[float], None] = time.sleep,
                       label: str = "") -> T:
    """Call ``fn`` until it succeeds or ``retries`` retryable failures.

    Non-retryable exceptions (including ``PreemptionFault``) propagate
    immediately; the last retryable failure is re-raised unchanged.
    """
    attempt = 0
    while True:
        try:
            return fn()
        except retryable as e:                         # noqa: PERF203
            attempt += 1
            if attempt > retries:
                raise
            d = backoff_delay(attempt, base_delay=base_delay, factor=factor,
                              max_delay_s=max_delay_s,
                              jitter_frac=jitter_frac,
                              jitter_seed=jitter_seed, label=label)
            obs.metrics.counter("resilience_retries_total",
                                label=label or "unlabeled").inc()
            obs.event("retry", label=label, attempt=attempt,
                      delay_s=d, error=repr(e))
            if d > 0:
                sleep(d)


class BatchSkipList:
    """Failure counts per batch key; a batch that causes ``skip_after``
    rollbacks is poison and gets skipped on the next replay.

    Keys are (epoch, batch_index) — the deterministic identity of a batch in
    the resumable iterator, stable across rollbacks and process restarts
    within one plan. ``state()`` / ``restore()`` round-trip through the
    checkpoint ``extra`` payload so poison status survives a preemption
    (a resumed process must not pay the discovery rollbacks again).
    """

    def __init__(self, skip_after: int = 2):
        self.skip_after = max(1, int(skip_after))
        self._failures: Dict[Any, int] = {}
        self._skip: set = set()

    def record_failure(self, key: Any) -> bool:
        """Note a rollback caused at ``key``; True once it becomes poison."""
        n = self._failures.get(key, 0) + 1
        self._failures[key] = n
        if n >= self.skip_after:
            self._skip.add(key)
        return key in self._skip

    def should_skip(self, key: Any) -> bool:
        return key in self._skip

    @property
    def poisoned(self) -> set:
        return set(self._skip)

    # ------------------------------------------------------------------
    def state(self) -> dict:
        """JSON-able snapshot (tuple keys become lists on the wire)."""
        return {"failures": [[list(k), n]
                             for k, n in sorted(self._failures.items())],
                "skip": [list(k) for k in sorted(self._skip)]}

    def restore(self, state: Optional[dict]) -> None:
        """Merge a ``state()`` snapshot (failure counts take the max side,
        so an in-memory superset is never clobbered by an older snapshot)."""
        if not state:
            return
        for k, n in state.get("failures", []):
            key = tuple(k)
            self._failures[key] = max(self._failures.get(key, 0), int(n))
        for k in state.get("skip", []):
            self._skip.add(tuple(k))


# --------------------------------------------------------------------------
# crash-consistency contract for the checkpoint ``extra`` payload


def pack_train_extra(step: int, data_state: Dict[str, int],
                     epoch_log: EpochLog,
                     skiplist: Optional[BatchSkipList] = None) -> dict:
    extra = {"step": int(step), "data_state": dict(data_state),
             "epoch_log": epoch_log.to_jsonable()}
    if skiplist is not None:
        extra["skiplist"] = skiplist.state()
    return extra


def unpack_train_extra(extra: dict) -> Tuple[int, Optional[Dict[str, int]],
                                             Optional[EpochLog],
                                             Optional[dict]]:
    step = int(extra["step"])
    data_state = extra.get("data_state")
    log = EpochLog.from_jsonable(extra["epoch_log"]) \
        if "epoch_log" in extra else None
    return step, data_state, log, extra.get("skiplist")
